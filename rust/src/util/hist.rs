//! Log-bucketed latency histogram (hdr-style) for the serving load harness.
//!
//! Linear-log bucketing with `GROUP_BITS = 5`: values below `2^(g+1)` get
//! exact unit-width buckets; above that, each power-of-two range is split
//! into `2^g = 32` equal sub-buckets, so the relative quantile error is
//! bounded by `2^-g` (≈3.1%) at any magnitude.  This is the same layout
//! HdrHistogram uses for its sub-bucket arrays, shrunk to the one
//! resolution the load harness needs; with 64-bit values the index space
//! tops out below 1,952 buckets, so a flat `Vec<u64>` is the whole data
//! structure and merging two histograms is element-wise addition —
//! associative and commutative by construction (property-tested below).
//!
//! Units are whatever the caller records (the serving harness records
//! nanoseconds); the histogram itself is unit-agnostic.

use crate::util::json::Json;

/// Sub-bucket resolution: each power-of-two range splits into `2^GROUP_BITS`
/// buckets, bounding relative error by `2^-GROUP_BITS`.
pub const GROUP_BITS: u32 = 5;

/// Below this value every bucket has width 1 (exact counts).
const LINEAR_MAX: u64 = 1 << (GROUP_BITS + 1);

/// Flat bucket index of `v`.  Continuous at the linear/log boundary:
/// `bucket(LINEAR_MAX - 1) + 1 == bucket(LINEAR_MAX)`.
fn bucket(v: u64) -> usize {
    if v < LINEAR_MAX {
        v as usize
    } else {
        // m = position of the highest set bit, >= GROUP_BITS + 1 here
        let m = 63 - v.leading_zeros();
        let shift = m - GROUP_BITS;
        (((shift as u64) << GROUP_BITS) + (v >> shift)) as usize
    }
}

/// Inclusive lower bound of bucket `i` (inverse of [`bucket`]).
fn bucket_lo(i: usize) -> u64 {
    let i = i as u64;
    if i < LINEAR_MAX {
        i
    } else {
        let shift = (i >> GROUP_BITS) - 1;
        let sub = i - (shift << GROUP_BITS);
        sub << shift
    }
}

/// Exclusive upper bound of bucket `i`.
fn bucket_hi(i: usize) -> u64 {
    let i = i as u64;
    if i < LINEAR_MAX {
        i + 1
    } else {
        let shift = (i >> GROUP_BITS) - 1;
        let sub = i - (shift << GROUP_BITS);
        (sub + 1) << shift
    }
}

/// Representative value reported for bucket `i`: the bucket midpoint, which
/// keeps the worst-case quantile error at half the bucket width.
fn bucket_mid(i: usize) -> u64 {
    let lo = bucket_lo(i);
    let hi = bucket_hi(i);
    lo + (hi - lo) / 2
}

/// Log-bucketed histogram of `u64` samples.
///
/// `record` is O(1); `quantile` walks the (bounded) bucket array.  `merge`
/// is element-wise and lossless: merging per-connection histograms yields
/// bit-identical quantiles to recording every sample into one histogram.
#[derive(Clone, Debug, Default)]
pub struct Hist {
    counts: Vec<u64>,
    n: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Hist {
    pub fn new() -> Hist {
        Hist { counts: Vec::new(), n: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    pub fn record(&mut self, v: u64) {
        self.record_n(v, 1);
    }

    /// Record `weight` occurrences of `v`.
    pub fn record_n(&mut self, v: u64, weight: u64) {
        if weight == 0 {
            return;
        }
        let i = bucket(v);
        if self.counts.len() <= i {
            self.counts.resize(i + 1, 0);
        }
        if let Some(c) = self.counts.get_mut(i) {
            *c += weight;
        }
        self.n += weight;
        self.sum += (v as u128) * (weight as u128);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold `other` into `self` (element-wise; associative + commutative).
    pub fn merge(&mut self, other: &Hist) {
        if other.n == 0 {
            return;
        }
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += *o;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn min(&self) -> u64 {
        if self.n == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]`: the midpoint of the bucket holding
    /// the `ceil(q·n)`-th smallest sample (exact `min`/`max` at the ends).
    /// Worst-case relative error vs. the exact sorted sample is `2^-GROUP_BITS`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        if q <= 0.0 {
            return self.min();
        }
        if q >= 1.0 {
            return self.max;
        }
        let rank = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // clamp the representative to the observed range so p50 of
                // a single-value histogram returns that value exactly
                return bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// JSON dump for CI artifacts: summary quantiles plus the non-empty
    /// buckets as `[lo, hi, count]` triples.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(i, c)| {
                Json::Arr(vec![
                    Json::Num(bucket_lo(i) as f64),
                    Json::Num(bucket_hi(i) as f64),
                    Json::Num(*c as f64),
                ])
            })
            .collect();
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("min", Json::Num(self.min() as f64)),
            ("max", Json::Num(self.max as f64)),
            ("mean", Json::Num(self.mean())),
            ("p50", Json::Num(self.p50() as f64)),
            ("p90", Json::Num(self.quantile(0.90) as f64)),
            ("p99", Json::Num(self.p99() as f64)),
            ("p999", Json::Num(self.p999() as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

impl PartialEq for Hist {
    /// Structural equality up to trailing empty buckets, so merge order
    /// (which only affects how far `counts` grew) never breaks equality.
    fn eq(&self, other: &Hist) -> bool {
        let trim = |c: &[u64]| {
            let end = c.iter().rposition(|x| *x > 0).map_or(0, |p| p + 1);
            c.get(..end).map(|s| s.to_vec()).unwrap_or_default()
        };
        self.n == other.n
            && self.sum == other.sum
            && self.min() == other.min()
            && self.max == other.max
            && trim(&self.counts) == trim(&other.counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_index_is_monotone_and_continuous() {
        // exhaustive near the linear/log boundary, sampled above it
        let mut prev = bucket(0);
        for v in 1..4096u64 {
            let b = bucket(v);
            assert!(b == prev || b == prev + 1, "gap at v={v}");
            prev = b;
        }
        for shift in 7..63u32 {
            let v = 1u64 << shift;
            assert_eq!(bucket(v - 1) + 1, bucket(v), "boundary at 2^{shift}");
        }
    }

    #[test]
    fn bucket_bounds_invert_the_index() {
        let mut rng = Rng::new(7);
        for _ in 0..20_000 {
            let v = rng.next_u64() >> (rng.below(60) as u32);
            let i = bucket(v);
            assert!(bucket_lo(i) <= v && v < bucket_hi(i), "v={v} i={i}");
        }
    }

    #[test]
    fn linear_region_is_exact() {
        let mut h = Hist::new();
        for v in 0..LINEAR_MAX {
            h.record(v);
        }
        // rank ceil(0.5 * 64) = 32 => the 32nd smallest of 0..64 is 31
        assert_eq!(h.quantile(0.5), LINEAR_MAX / 2 - 1);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), LINEAR_MAX - 1);
    }

    #[test]
    fn empty_hist_is_all_zeros() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    /// Exact quantile by sort, matching the histogram's rank convention.
    fn exact_quantile(sorted: &[u64], q: f64) -> u64 {
        let n = sorted.len() as u64;
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        sorted[(rank - 1) as usize]
    }

    #[test]
    fn quantiles_within_bucket_error_of_exact_sort() {
        for_cases(40, 0x9157_0001, |rng, case| {
            let n = 1 + rng.below(2000) as usize;
            // heavy-tailed sample spanning the linear and log regions,
            // roughly "nanosecond latencies from 0 to seconds"
            let samples: Vec<u64> = (0..n)
                .map(|_| {
                    let mag = rng.below(30);
                    rng.next_u64() % (1u64 << (mag + 4))
                })
                .collect();
            let mut h = Hist::new();
            for s in &samples {
                h.record(*s);
            }
            let mut sorted = samples.clone();
            sorted.sort_unstable();
            for q in [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
                let exact = exact_quantile(&sorted, q);
                let got = h.quantile(q);
                // same bucket => |got - exact| < bucket width <= exact * 2^-g
                let tol = (exact >> GROUP_BITS).max(0);
                assert!(
                    got.abs_diff(exact) <= tol,
                    "case {case}: q={q} exact={exact} got={got} tol={tol}"
                );
            }
            assert_eq!(h.quantile(0.0), sorted[0]);
            assert_eq!(h.quantile(1.0), sorted[n - 1]);
        });
    }

    #[test]
    fn merge_is_associative_commutative_and_lossless() {
        for_cases(40, 0x9157_0002, |rng, case| {
            let mut parts: Vec<Hist> = Vec::new();
            let mut bulk = Hist::new();
            for _ in 0..3 {
                let mut h = Hist::new();
                for _ in 0..rng.below(400) {
                    let v = rng.next_u64() % (1u64 << (4 + rng.below(40)));
                    h.record(v);
                    bulk.record(v);
                }
                parts.push(h);
            }
            let (a, b, c) = (&parts[0], &parts[1], &parts[2]);
            // (a + b) + c
            let mut left = a.clone();
            left.merge(b);
            left.merge(c);
            // a + (b + c)
            let mut bc = b.clone();
            bc.merge(c);
            let mut right = a.clone();
            right.merge(&bc);
            assert!(left == right, "case {case}: associativity");
            // b + a == a + b
            let mut ab = a.clone();
            ab.merge(b);
            let mut ba = b.clone();
            ba.merge(a);
            assert!(ab == ba, "case {case}: commutativity");
            // merged parts == recording everything into one histogram
            assert!(left == bulk, "case {case}: merge vs bulk");
            for q in [0.5, 0.99, 0.999] {
                assert_eq!(left.quantile(q), bulk.quantile(q), "case {case}: q={q}");
            }
        });
    }

    #[test]
    fn record_n_weights_counts() {
        let mut h = Hist::new();
        h.record_n(100, 5);
        h.record_n(1000, 1);
        assert_eq!(h.count(), 6);
        assert!(h.quantile(0.5).abs_diff(100) <= 100 >> GROUP_BITS);
        assert_eq!(h.max(), 1000);
        h.record_n(7, 0); // zero weight is a no-op
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn json_dump_has_summary_and_buckets() {
        let mut h = Hist::new();
        for v in [10u64, 20, 20, 4000] {
            h.record(v);
        }
        let j = h.to_json().to_string();
        assert!(j.contains("\"n\":4"), "{j}");
        assert!(j.contains("\"buckets\""), "{j}");
    }
}
