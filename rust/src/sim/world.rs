//! World simulator: deterministic per-(prompt, model) reward and cost
//! matrices (DESIGN.md §6 substitution for live LLM APIs + judge scoring).
//!
//! The paper's own evaluation is fully offline over a fixed reward–cost
//! matrix (§6 Limitations); this module regenerates a matrix whose marginal
//! statistics match the paper's anchors (DESIGN.md §4): Table-1 pricing and
//! mean qualities, the 0.963 oracle, per-model cost CVs, the shared
//! output-length factor behind cross-model cost correlation, and the three
//! correlated judge surrogates of Appendix E.

use super::corpus::Prompt;
use crate::util::rng::mix2;

/// Standard-normal draw keyed on (a, b, salt) — stateless, so every
/// (prompt, model) cell of the matrix is deterministic.
fn key_normal(a: u64, b: u64, salt: u64) -> f64 {
    let u1 = ((mix2(a, b ^ salt) >> 11) as f64 / (1u64 << 53) as f64).max(1e-16);
    let u2 = (mix2(b ^ salt, a.wrapping_add(salt)) >> 11) as f64 / (1u64 << 53) as f64;
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// A simulated LLM endpoint: pricing + quality surface + output-length
/// distribution.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    pub tier: &'static str,
    /// list price, $ / 1M input tokens
    pub price_in_per_m: f64,
    /// list price, $ / 1M output tokens
    pub price_out_per_m: f64,
    /// quality intercept
    pub base_q: f64,
    /// quality loss per unit difficulty
    pub diff_slope: f64,
    /// per-benchmark quality affinity
    pub affinity: [f64; 9],
    /// idiosyncratic per-(prompt,model) quality noise sd
    pub idio_sd: f64,
    /// lognormal output-token parameters
    pub out_mu: f64,
    pub out_sigma: f64,
    /// weight of the shared per-prompt verbosity factor in output length
    pub verbosity_w: f64,
}

impl ModelSpec {
    /// Blended $/1k-token rate (1:1 in/out blend, Appendix B).
    pub fn blended_per_1k(&self) -> f64 {
        (self.price_in_per_m + self.price_out_per_m) / 2.0 / 1000.0
    }
}

/// Model ids in the canonical K=4 bank.
pub const LLAMA: usize = 0;
pub const MISTRAL: usize = 1;
pub const GEMINI_PRO: usize = 2;
pub const FLASH: usize = 3;

/// Gemini-Flash onboarding scenario (§4.5 / Figure 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlashScenario {
    /// good quality at a cheap price — should be adopted at all budgets
    GoodCheap,
    /// good quality, Gemini-Pro-class price — budget-gated
    GoodExpensive,
    /// poor quality at a cheap price — rejected after burn-in
    BadCheap,
}

/// Table-1 three-tier portfolio (+ the K=4 Flash extension).
pub fn model_bank(flash: FlashScenario) -> Vec<ModelSpec> {
    let mut bank = vec![
        ModelSpec {
            name: "llama-3.1-8b",
            tier: "budget",
            price_in_per_m: 0.10,
            price_out_per_m: 0.10,
            // the 8B model holds its own on easy prompts but collapses on
            // hard reasoning (its easy-bench conditional mean stays just
            // below mistral's penalized score, so the unconstrained router
            // is mistral/gemini-dominant as in the paper, while the oracle
            // still gains from idiosyncratic llama wins)
            base_q: 0.920,
            diff_slope: 0.22,
            affinity: [0.005, -0.015, 0.015, -0.02, 0.005, 0.01, 0.01, -0.01, -0.015],
            idio_sd: 0.07,
            out_mu: 5.262,
            out_sigma: 0.594,
            verbosity_w: 0.75,
        },
        ModelSpec {
            name: "mistral-large",
            tier: "mid-cost",
            price_in_per_m: 0.40,
            price_out_per_m: 1.60,
            // strong generalist that visibly dips on the hardest reasoning
            // benchmarks — the gap Gemini-Pro's premium buys back
            base_q: 0.9755,
            diff_slope: 0.045,
            affinity: [0.01, -0.09, 0.015, -0.12, 0.01, 0.015, 0.015, -0.03, -0.09],
            idio_sd: 0.035,
            out_mu: 5.508,
            out_sigma: 0.703,
            verbosity_w: 0.75,
        },
        ModelSpec {
            name: "gemini-2.5-pro",
            tier: "frontier",
            price_in_per_m: 1.25,
            price_out_per_m: 10.0,
            base_q: 0.9566,
            diff_slope: 0.025,
            // uniformly strong: on hard reasoning benchmarks (where llama
            // collapses and mistral dips) its conditional edge exceeds the
            // static cost-penalty gap, making selective Gemini routing
            // worthwhile (paper Fig. 1c "Selective Gemini")
            affinity: [-0.02, 0.03, -0.03, 0.03, -0.02, -0.03, -0.03, 0.02, 0.03],
            idio_sd: 0.035,
            out_mu: 7.010,
            out_sigma: 0.771,
            verbosity_w: 0.75,
        },
    ];
    bank.push(match flash {
        FlashScenario::GoodCheap => ModelSpec {
            name: "gemini-2.5-flash",
            tier: "fast",
            price_in_per_m: 0.30,
            price_out_per_m: 2.50,
            base_q: 0.950,
            diff_slope: 0.050,
            affinity: [0.01, 0.0, 0.01, -0.01, 0.01, 0.01, 0.0, 0.0, 0.0],
            idio_sd: 0.04,
            out_mu: 5.55,
            out_sigma: 1.10,
            verbosity_w: 0.60,
        },
        FlashScenario::GoodExpensive => ModelSpec {
            name: "gemini-2.5-flash",
            tier: "fast",
            price_in_per_m: 1.25,
            price_out_per_m: 10.0,
            base_q: 0.950,
            diff_slope: 0.050,
            affinity: [0.01, 0.0, 0.01, -0.01, 0.01, 0.01, 0.0, 0.0, 0.0],
            idio_sd: 0.04,
            out_mu: 6.95,
            out_sigma: 0.80,
            verbosity_w: 0.60,
        },
        FlashScenario::BadCheap => ModelSpec {
            name: "gemini-2.5-flash",
            tier: "fast",
            price_in_per_m: 0.30,
            price_out_per_m: 2.50,
            base_q: 0.70,
            diff_slope: 0.25,
            affinity: [0.0; 9],
            idio_sd: 0.05,
            out_mu: 5.55,
            out_sigma: 1.10,
            verbosity_w: 0.60,
        },
    });
    bank
}

/// The three judge surrogates (Appendix E).  R1 is the primary reward.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Judge {
    R1 = 0,
    GptMini = 1,
    Claude = 2,
}

pub const JUDGES: [Judge; 3] = [Judge::R1, Judge::GptMini, Judge::Claude];

/// Environment drift applied to one phase of a scenario (§4.3–4.4).
#[derive(Clone, Debug)]
pub struct EnvView {
    /// multiplier on both token prices, per model (cost drift)
    pub price_mult: Vec<f64>,
    /// silent quality regression: shift model m's reward so its mean
    /// equals the target (Appendix G mean-shift protocol)
    pub reward_mean_to: Vec<Option<f64>>,
}

impl EnvView {
    pub fn normal(k: usize) -> EnvView {
        EnvView {
            price_mult: vec![1.0; k],
            reward_mean_to: vec![None; k],
        }
    }

    /// Scale one model's prices (e.g. Gemini → $0.10/M ≈ mult 0.0178).
    pub fn with_price_mult(mut self, model: usize, mult: f64) -> EnvView {
        self.price_mult[model] = mult;
        self
    }

    /// Degrade one model's mean reward to `target` (cost unchanged).
    pub fn with_degraded(mut self, model: usize, target: f64) -> EnvView {
        self.reward_mean_to[model] = Some(target);
        self
    }
}

/// The deterministic world: reward/cost oracle over (prompt, model).
pub struct World {
    pub models: Vec<ModelSpec>,
    seed: u64,
    /// per-model baseline mean reward (R1), used by mean-shift degradation
    base_mean: Vec<f64>,
}

const SALT_QUALITY: u64 = 0x51;
const SALT_OUT: u64 = 0x07;
const SALT_JUDGE: [u64; 3] = [0xA1, 0xA2, 0xA3];

impl World {
    /// Build a world over a model bank.  `calib` prompts (typically the
    /// whole corpus) are used to estimate baseline per-model means for the
    /// mean-shift degradation protocol.
    pub fn new(models: Vec<ModelSpec>, seed: u64, calib: &[Prompt]) -> World {
        let mut w = World {
            base_mean: vec![0.0; models.len()],
            models,
            seed,
        };
        for m in 0..w.models.len() {
            let mut s = 0.0;
            for p in calib.iter().take(4000) {
                s += w.quality(p, m);
            }
            w.base_mean[m] = s / calib.len().min(4000) as f64;
        }
        w
    }

    pub fn k(&self) -> usize {
        self.models.len()
    }

    /// Latent true quality q(prompt, model) ∈ [0,1].
    pub fn quality(&self, p: &Prompt, model: usize) -> f64 {
        let spec = &self.models[model];
        let idio = spec.idio_sd * key_normal(self.seed ^ p.id as u64, model as u64, SALT_QUALITY);
        (spec.base_q - spec.diff_slope * p.difficulty + spec.affinity[p.bench] + idio)
            .clamp(0.0, 1.0)
    }

    /// Judge-scored reward (deterministic per (judge, prompt, model)).
    /// R1 tracks latent quality closely (largest inter-model gaps);
    /// GPT-mini compresses gaps upward; Claude is slightly harsher.
    /// Calibrated to Appendix E's Table 6 means and ~0.63–0.66 Spearman.
    pub fn judge_reward(&self, judge: Judge, p: &Prompt, model: usize) -> f64 {
        let q = self.quality(p, model);
        let n = key_normal(
            self.seed ^ p.id as u64,
            model as u64 ^ 0x9000,
            SALT_JUDGE[judge as usize],
        );
        let r = match judge {
            Judge::R1 => q + 0.020 * n,
            Judge::GptMini => 0.26 + 0.74 * q + 0.080 * n,
            Judge::Claude => q - 0.012 + 0.085 * n,
        };
        r.clamp(0.0, 1.0)
    }

    /// Primary reward signal (DeepSeek-R1 surrogate).
    #[inline]
    pub fn reward(&self, p: &Prompt, model: usize) -> f64 {
        self.judge_reward(Judge::R1, p, model)
    }

    /// Reward under a drifted view (mean-shift degradation, Appendix G).
    pub fn reward_view(&self, p: &Prompt, model: usize, view: &EnvView) -> f64 {
        let r = self.reward(p, model);
        match view.reward_mean_to[model] {
            Some(target) => (r + target - self.base_mean[model]).clamp(0.0, 1.0),
            None => r,
        }
    }

    /// Deterministic output tokens for (prompt, model): lognormal with a
    /// shared per-prompt verbosity factor (drives the paper's 0.56–0.68
    /// cross-model cost correlation).
    pub fn out_tokens(&self, p: &Prompt, model: usize) -> f64 {
        let spec = &self.models[model];
        let w = spec.verbosity_w;
        let idio = key_normal(self.seed ^ p.id as u64, model as u64 ^ 0x7000, SALT_OUT);
        let z = w * p.verbosity + (1.0 - w * w).sqrt() * idio;
        (spec.out_mu + spec.out_sigma * z).exp()
    }

    /// Realised per-request cost in dollars at list prices.
    pub fn cost(&self, p: &Prompt, model: usize) -> f64 {
        let spec = &self.models[model];
        (p.in_tokens() * spec.price_in_per_m + self.out_tokens(p, model) * spec.price_out_per_m)
            / 1e6
    }

    /// Cost under a drifted view (price multipliers).
    pub fn cost_view(&self, p: &Prompt, model: usize, view: &EnvView) -> f64 {
        self.cost(p, model) * view.price_mult[model]
    }

    /// Baseline mean R1 reward for a model (mean-shift anchor).
    pub fn base_mean(&self, model: usize) -> f64 {
        self.base_mean[model]
    }

    /// Oracle reward for a prompt: best model under judge `j`.
    pub fn oracle_reward(&self, judge: Judge, p: &Prompt, k: usize) -> f64 {
        (0..k)
            .map(|m| self.judge_reward(judge, p, m))
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Oracle arm under judge `j` over the first `k` models.
    pub fn oracle_arm(&self, judge: Judge, p: &Prompt, k: usize) -> usize {
        let mut best = 0;
        let mut bv = f64::NEG_INFINITY;
        for m in 0..k {
            let r = self.judge_reward(judge, p, m);
            if r > bv {
                bv = r;
                best = m;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::corpus::Corpus;

    fn setup() -> (Corpus, World) {
        let c = Corpus::build(42);
        let w = World::new(model_bank(FlashScenario::GoodCheap), 42, &c.prompts);
        (c, w)
    }

    fn mean<F: Fn(&Prompt) -> f64>(ps: &[Prompt], f: F) -> f64 {
        ps.iter().map(|p| f(p)).sum::<f64>() / ps.len() as f64
    }

    #[test]
    fn mean_rewards_match_table1_anchors() {
        let (c, w) = setup();
        let ml = mean(&c.prompts, |p| w.reward(p, LLAMA));
        let mm = mean(&c.prompts, |p| w.reward(p, MISTRAL));
        let mg = mean(&c.prompts, |p| w.reward(p, GEMINI_PRO));
        assert!((ml - 0.793).abs() < 0.015, "llama mean {ml}");
        assert!((mm - 0.923).abs() < 0.012, "mistral mean {mm}");
        assert!((mg - 0.932).abs() < 0.012, "gemini mean {mg}");
        assert!(mg > mm && mm > ml, "ordering");
    }

    #[test]
    fn oracle_mean_matches_paper() {
        let (c, w) = setup();
        let oracle = mean(&c.prompts, |p| w.oracle_reward(Judge::R1, p, 3));
        assert!((oracle - 0.963).abs() < 0.012, "oracle {oracle}");
    }

    #[test]
    fn mean_costs_match_table1() {
        let (c, w) = setup();
        let cl = mean(&c.prompts, |p| w.cost(p, LLAMA));
        let cm = mean(&c.prompts, |p| w.cost(p, MISTRAL));
        let cg = mean(&c.prompts, |p| w.cost(p, GEMINI_PRO));
        assert!((cl / 2.9e-5 - 1.0).abs() < 0.25, "llama ${cl}");
        assert!((cm / 5.3e-4 - 1.0).abs() < 0.25, "mistral ${cm}");
        assert!((cg / 1.5e-2 - 1.0).abs() < 0.25, "gemini ${cg}");
        // the 530x spread
        assert!(cg / cl > 300.0 && cg / cl < 900.0, "spread {}", cg / cl);
    }

    #[test]
    fn cost_cvs_in_paper_band() {
        let (c, w) = setup();
        let cv = |m: usize| {
            let costs: Vec<f64> = c.prompts.iter().map(|p| w.cost(p, m)).collect();
            let mu = costs.iter().sum::<f64>() / costs.len() as f64;
            let var = costs.iter().map(|c| (c - mu).powi(2)).sum::<f64>() / costs.len() as f64;
            var.sqrt() / mu
        };
        for m in [LLAMA, MISTRAL, GEMINI_PRO] {
            let v = cv(m);
            assert!(v > 0.45 && v < 1.1, "model {m} CV {v}");
        }
        let vf = cv(FLASH);
        assert!(vf > 1.1 && vf < 2.2, "flash CV {vf}"); // paper: 1.56
    }

    #[test]
    fn deterministic_matrix() {
        let (c, w) = setup();
        let p = &c.prompts[17];
        assert_eq!(w.reward(p, 1), w.reward(p, 1));
        assert_eq!(w.cost(p, 2), w.cost(p, 2));
    }

    #[test]
    fn degradation_view_shifts_mean_only_for_target() {
        let (c, w) = setup();
        let view = EnvView::normal(4).with_degraded(MISTRAL, 0.75);
        let mm = mean(&c.prompts, |p| w.reward_view(p, MISTRAL, &view));
        let ml = mean(&c.prompts, |p| w.reward_view(p, LLAMA, &view));
        assert!((mm - 0.75).abs() < 0.02, "degraded mean {mm}");
        assert!((ml - 0.793).abs() < 0.015, "llama untouched {ml}");
        // cost unchanged under quality degradation
        let p = &c.prompts[3];
        assert_eq!(w.cost_view(p, MISTRAL, &view), w.cost(p, MISTRAL));
    }

    #[test]
    fn price_drop_view_scales_cost_only() {
        let (c, w) = setup();
        // Gemini $0.10/M on both sides ≈ blended mult 0.10/5.625e0 per-token
        let mult = 0.10 / ((1.25 + 10.0) / 2.0);
        let view = EnvView::normal(4).with_price_mult(GEMINI_PRO, mult);
        let p = &c.prompts[9];
        assert!((w.cost_view(p, GEMINI_PRO, &view) / w.cost(p, GEMINI_PRO) - mult).abs() < 1e-12);
        assert_eq!(w.reward_view(p, GEMINI_PRO, &view), w.reward(p, GEMINI_PRO));
    }

    #[test]
    fn judges_agree_on_global_ordering() {
        let (c, w) = setup();
        for j in JUDGES {
            let ml = mean(&c.prompts, |p| w.judge_reward(j, p, LLAMA));
            let mm = mean(&c.prompts, |p| w.judge_reward(j, p, MISTRAL));
            let mg = mean(&c.prompts, |p| w.judge_reward(j, p, GEMINI_PRO));
            assert!(mg > mm && mm > ml, "judge {j:?}: {mg} {mm} {ml}");
        }
    }

    #[test]
    fn gpt_judge_compresses_upward() {
        // Table 6: GPT-4.1-mini scores are uniformly higher
        let (c, w) = setup();
        let r1 = mean(&c.prompts, |p| w.judge_reward(Judge::R1, p, LLAMA));
        let gpt = mean(&c.prompts, |p| w.judge_reward(Judge::GptMini, p, LLAMA));
        assert!(gpt > r1 + 0.03, "gpt {gpt} vs r1 {r1}");
    }

    #[test]
    fn flash_scenarios_differ_as_specified() {
        let c = Corpus::build(42);
        let good = World::new(model_bank(FlashScenario::GoodCheap), 42, &c.prompts);
        let bad = World::new(model_bank(FlashScenario::BadCheap), 42, &c.prompts);
        let exp = World::new(model_bank(FlashScenario::GoodExpensive), 42, &c.prompts);
        let mg = mean(&c.prompts, |p| good.reward(p, FLASH));
        let mb = mean(&c.prompts, |p| bad.reward(p, FLASH));
        assert!(mg > 0.88 && mb < 0.65, "good {mg} bad {mb}");
        let cost_good = mean(&c.prompts, |p| good.cost(p, FLASH));
        let cost_exp = mean(&c.prompts, |p| exp.cost(p, FLASH));
        assert!(cost_exp > cost_good * 5.0);
    }

    #[test]
    fn difficulty_monotonicity_llama_vs_gemini() {
        // llama's edge is easy prompts; gemini must win on hard ones
        let (c, w) = setup();
        let easy: Vec<&Prompt> = c.prompts.iter().filter(|p| p.difficulty < 0.2).collect();
        let hard: Vec<&Prompt> = c.prompts.iter().filter(|p| p.difficulty > 0.8).collect();
        assert!(easy.len() > 50 && hard.len() > 50);
        let win = |ps: &[&Prompt]| {
            ps.iter()
                .filter(|p| w.quality(p, LLAMA) > w.quality(p, GEMINI_PRO))
                .count() as f64
                / ps.len() as f64
        };
        // llama's (idiosyncratic) wins concentrate on easy prompts; on hard
        // reasoning prompts the frontier model is near-unbeatable
        assert!(win(&easy) > 0.10, "llama should win some easy: {}", win(&easy));
        assert!(
            win(&easy) > 4.0 * win(&hard).max(1e-3),
            "easy {} vs hard {}",
            win(&easy),
            win(&hard)
        );
        assert!(win(&hard) < 0.05, "gemini should win hard: {}", win(&hard));
    }
}
