//! Synthetic prompt corpus — the paper's nine-benchmark workload
//! (DESIGN.md §6 substitution for the 11,983 real benchmark prompts).
//!
//! Each prompt belongs to one of nine benchmark families with a
//! family-specific vocabulary mix, length range and latent difficulty; the
//! vocabulary *specification* matches `python/compile/simcorpus.py` so the
//! AOT featurizer clusters prompts by family exactly as a sentence encoder
//! clusters real prompts by topic.

use crate::util::rng::{mix2, Rng};

/// (name, specific-word ratio, min words, max words, base difficulty)
/// First four fields mirror python's `simcorpus.BENCHMARKS`; the base
/// difficulty drives the world simulator's quality surfaces.
pub const BENCHMARKS: [(&str, f64, usize, usize, f64); 9] = [
    ("mmlu", 0.55, 18, 60, 0.55),
    ("gsm8k", 0.65, 30, 90, 0.75),
    ("hellaswag", 0.45, 25, 70, 0.30),
    ("bbh", 0.60, 20, 80, 0.85),
    ("arc", 0.50, 15, 50, 0.50),
    ("openbookqa", 0.50, 12, 45, 0.40),
    ("winogrande", 0.40, 15, 40, 0.35),
    ("truthfulqa", 0.45, 10, 40, 0.60),
    ("mbpp", 0.70, 20, 85, 0.70),
];

pub const N_BENCH: usize = 9;
const N_SHARED: usize = 200;
const N_SPECIFIC: usize = 120;

/// Paper split sizes (§4.1).
pub const N_TRAIN: usize = 8374;
pub const N_VAL: usize = 1785;
pub const N_TEST: usize = 1824;
pub const N_TOTAL: usize = N_TRAIN + N_VAL + N_TEST; // 11,983

/// One synthetic prompt with its latent generative state.
#[derive(Clone, Debug)]
pub struct Prompt {
    /// global prompt id (stable across runs)
    pub id: u32,
    /// benchmark family index
    pub bench: usize,
    /// word count
    pub n_words: usize,
    /// latent difficulty in [0,1] (drives model quality surfaces)
    pub difficulty: f64,
    /// latent verbosity factor ~ N(0,1) (drives shared output length)
    pub verbosity: f64,
    /// prompt text (family-clustered synthetic words)
    pub text: String,
}

impl Prompt {
    /// Estimated input tokens (≈ 1.3 tokens/word).
    #[inline]
    pub fn in_tokens(&self) -> f64 {
        self.n_words as f64 * 1.3
    }
}

/// The three stratified splits (train fits priors, val tunes, test
/// evaluates — §4.1).
pub struct Corpus {
    pub prompts: Vec<Prompt>,
    pub train: Vec<u32>,
    pub val: Vec<u32>,
    pub test: Vec<u32>,
}

/// Deterministic per-prompt generation keyed on (corpus_seed, prompt_id).
fn gen_prompt(corpus_seed: u64, id: u32) -> Prompt {
    let mut rng = Rng::new(mix2(corpus_seed, id as u64));
    let bench = (id as usize) % N_BENCH;
    let (name, ratio, lo, hi, base_diff) = BENCHMARKS[bench];
    let n_words = rng.range(lo, hi);
    let mut words = Vec::with_capacity(n_words);
    for _ in 0..n_words {
        if rng.bernoulli(ratio) {
            words.push(format!("{name}_{}", rng.below(N_SPECIFIC)));
        } else {
            words.push(format!("w{}", rng.below(N_SHARED)));
        }
    }
    let difficulty = (base_diff + 0.18 * rng.normal()).clamp(0.0, 1.0);
    // verbosity correlates weakly with prompt length (drives the paper's
    // ρ=0.12–0.27 word-count ↔ cost correlation, Appendix B)
    let len_z = (n_words as f64 - (lo + hi) as f64 / 2.0) / ((hi - lo) as f64 / 3.46);
    let verbosity = 0.30 * len_z + 0.954 * rng.normal();
    Prompt {
        id,
        bench,
        n_words,
        difficulty,
        verbosity,
        text: words.join(" "),
    }
}

impl Corpus {
    /// Build the full 11,983-prompt corpus with stratified splits.
    pub fn build(seed: u64) -> Corpus {
        let prompts: Vec<Prompt> = (0..N_TOTAL as u32).map(|id| gen_prompt(seed, id)).collect();
        // stratified split: shuffle ids within each benchmark family, then
        // cut proportionally (largest-remainder rounding to hit the exact
        // paper counts).
        let mut per_bench: Vec<Vec<u32>> = vec![Vec::new(); N_BENCH];
        for p in &prompts {
            per_bench[p.bench].push(p.id);
        }
        let mut rng = Rng::new(mix2(seed, 0xDEAD_BEEF));
        for ids in &mut per_bench {
            rng.shuffle(ids);
        }
        let (mut train, mut val, mut test) = (Vec::new(), Vec::new(), Vec::new());
        for ids in &per_bench {
            let n = ids.len();
            let n_tr = (n * N_TRAIN + N_TOTAL / 2) / N_TOTAL;
            let n_va = (n * N_VAL + N_TOTAL / 2) / N_TOTAL;
            train.extend(&ids[..n_tr]);
            val.extend(&ids[n_tr..n_tr + n_va]);
            test.extend(&ids[n_tr + n_va..]);
        }
        // largest-remainder fixups to hit exact global counts
        while train.len() > N_TRAIN {
            val.push(train.pop().unwrap());
        }
        while val.len() > N_VAL {
            test.push(val.pop().unwrap());
        }
        while train.len() < N_TRAIN {
            train.push(val.pop().unwrap());
        }
        while val.len() < N_VAL && test.len() > N_TEST {
            val.push(test.pop().unwrap());
        }
        Corpus {
            prompts,
            train,
            val,
            test,
        }
    }

    pub fn prompt(&self, id: u32) -> &Prompt {
        &self.prompts[id as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_sizes_match_paper() {
        let c = Corpus::build(1);
        assert_eq!(c.prompts.len(), 11_983);
        assert_eq!(c.train.len(), 8374);
        assert_eq!(c.val.len(), 1785);
        assert_eq!(c.test.len(), 1824);
    }

    #[test]
    fn splits_are_disjoint_and_cover() {
        let c = Corpus::build(2);
        let mut all: Vec<u32> = c
            .train
            .iter()
            .chain(c.val.iter())
            .chain(c.test.iter())
            .copied()
            .collect();
        all.sort_unstable();
        let n = all.len();
        all.dedup();
        assert_eq!(all.len(), n, "overlapping splits");
        assert_eq!(all.len(), N_TOTAL);
    }

    #[test]
    fn splits_are_stratified_by_source() {
        let c = Corpus::build(3);
        // each benchmark's share of the test split ≈ its corpus share
        for b in 0..N_BENCH {
            let share_test = c.test.iter().filter(|&&id| c.prompt(id).bench == b).count() as f64
                / c.test.len() as f64;
            assert!(
                (share_test - 1.0 / 9.0).abs() < 0.02,
                "bench {b} test share {share_test}"
            );
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = Corpus::build(7);
        let b = Corpus::build(7);
        let c = Corpus::build(8);
        assert_eq!(a.prompt(100).text, b.prompt(100).text);
        assert_ne!(a.prompt(100).text, c.prompt(100).text);
    }

    #[test]
    fn prompt_lengths_within_family_ranges() {
        let c = Corpus::build(4);
        for p in &c.prompts {
            let (_, _, lo, hi, _) = BENCHMARKS[p.bench];
            assert!(p.n_words >= lo && p.n_words <= hi);
            assert_eq!(p.text.split_whitespace().count(), p.n_words);
        }
    }

    #[test]
    fn difficulty_tracks_benchmark_base() {
        let c = Corpus::build(5);
        // gsm8k (0.75) must be harder on average than hellaswag (0.30)
        let mean = |b: usize| {
            let v: Vec<f64> = c
                .prompts
                .iter()
                .filter(|p| p.bench == b)
                .map(|p| p.difficulty)
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(mean(1) > mean(2) + 0.3);
    }

    #[test]
    fn vocab_is_family_clustered() {
        let c = Corpus::build(6);
        let p = c.prompts.iter().find(|p| p.bench == 0).unwrap();
        assert!(p.text.contains("mmlu_") || p.text.contains("w"));
        assert!(!p.text.contains("gsm8k_"));
    }
}
