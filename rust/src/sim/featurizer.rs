//! Pure-Rust surrogate featurizer.
//!
//! The *production* context path is the AOT-lowered JAX/Pallas featurizer
//! executed via PJRT (`runtime::Embedder`); experiments use it when
//! artifacts are present (cached context matrix).  This surrogate exists as
//! the artifact-free fallback so `cargo test` and the experiment harness
//! work in isolation: it produces whitened 26-d contexts with the same
//! information content (benchmark-family clusters + prompt length), which
//! is exactly what the real embedding exposes to the bandit.

use super::corpus::{Prompt, BENCHMARKS, N_BENCH};
use crate::util::rng::{mix2, Rng};

pub const D_CTX: usize = 26;

/// Deterministic text featurizer for serving *arbitrary* prompts without
/// PJRT artifacts (the [`SimFeaturizer`] above needs corpus `Prompt`
/// metadata).  Hashed bag-of-words: each token contributes a pseudo-random
/// direction in the `d-1` non-bias dims, the sum is scaled by 1/√n so dims
/// stay unit-ish variance, and the trailing dim is the bias 1 — the
/// whitened-context contract the router expects.  Used by the server's
/// surrogate fallback, the sharded-engine tests and the throughput bench.
pub fn hash_features(text: &str, d: usize) -> Vec<f64> {
    assert!(d >= 2, "need at least one feature dim plus bias");
    let mut x = vec![0.0; d];
    let mut n_tokens = 0u64;
    for tok in text.split_whitespace() {
        // FNV-1a over the token bytes
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for byte in tok.bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        n_tokens += 1;
        for (i, v) in x.iter_mut().take(d - 1).enumerate() {
            let u = (mix2(h, i as u64) >> 11) as f64 / (1u64 << 53) as f64;
            // uniform on [-√3, √3]: zero mean, unit variance per token
            *v += (u * 2.0 - 1.0) * 3f64.sqrt();
        }
    }
    if n_tokens > 0 {
        let s = 1.0 / (n_tokens as f64).sqrt();
        for v in x.iter_mut().take(d - 1) {
            *v *= s;
        }
    }
    x[d - 1] = 1.0;
    x
}

/// Deterministic whitened featurizer.
pub struct SimFeaturizer {
    /// per-benchmark cluster centroids in the 25 non-bias dims
    centroids: Vec<[f64; D_CTX - 1]>,
    /// direction carrying prompt-length information
    len_dir: [f64; D_CTX - 1],
    seed: u64,
}

impl SimFeaturizer {
    pub fn new(seed: u64) -> SimFeaturizer {
        let mut rng = Rng::new(mix2(seed, 0xFEA7));
        let mut centroids = Vec::with_capacity(N_BENCH);
        for _ in 0..N_BENCH {
            let mut c = [0.0; D_CTX - 1];
            for v in &mut c {
                *v = 0.80 * rng.normal();
            }
            centroids.push(c);
        }
        // demean across families so the context distribution is centered
        // (the real PCA featurizer centers by construction)
        for j in 0..D_CTX - 1 {
            let mean: f64 = centroids.iter().map(|c| c[j]).sum::<f64>() / N_BENCH as f64;
            for c in &mut centroids {
                c[j] -= mean;
            }
        }
        let mut len_dir = [0.0; D_CTX - 1];
        for v in &mut len_dir {
            *v = rng.normal() / ((D_CTX - 1) as f64).sqrt();
        }
        SimFeaturizer {
            centroids,
            len_dir,
            seed,
        }
    }

    /// Whitened 26-d context (unit-ish variance dims + trailing bias 1).
    pub fn context(&self, p: &Prompt) -> Vec<f64> {
        let (_, _, lo, hi, _) = BENCHMARKS[p.bench];
        let len_z = (p.n_words as f64 - (lo + hi) as f64 / 2.0) / ((hi - lo) as f64 / 3.46);
        let mut rng = Rng::new(mix2(self.seed ^ 0xC0, p.id as u64));
        let mut x = Vec::with_capacity(D_CTX);
        let c = &self.centroids[p.bench];
        for j in 0..D_CTX - 1 {
            x.push(c[j] + 0.30 * len_z * self.len_dir[j] + 0.55 * rng.normal());
        }
        x.push(1.0);
        x
    }

    /// Contexts for a whole prompt set.
    pub fn contexts(&self, prompts: &[Prompt]) -> Vec<Vec<f64>> {
        prompts.iter().map(|p| self.context(p)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::corpus::Corpus;

    #[test]
    fn hash_features_contract() {
        let a = hash_features("what is the capital of peru", 8);
        let b = hash_features("what is the capital of peru", 8);
        assert_eq!(a, b, "deterministic");
        assert_eq!(a.len(), 8);
        assert_eq!(a[7], 1.0, "bias dim");
        let c = hash_features("completely different text here", 8);
        assert_ne!(a, c, "distinct prompts must differ");
        // empty prompt still yields a valid (bias-only) context
        let e = hash_features("", 8);
        assert_eq!(e[7], 1.0);
        assert!(e[..7].iter().all(|&v| v == 0.0));
        // unit-ish variance over many prompts
        let xs: Vec<Vec<f64>> = (0..500)
            .map(|i| hash_features(&format!("prompt number {i} with words {}", i * 7), 8))
            .collect();
        for j in 0..7 {
            let var = xs.iter().map(|x| x[j] * x[j]).sum::<f64>() / xs.len() as f64;
            assert!(var > 0.2 && var < 3.0, "dim {j} var {var}");
        }
    }

    #[test]
    fn deterministic_and_bias_terminated() {
        let c = Corpus::build(1);
        let f = SimFeaturizer::new(1);
        let a = f.context(&c.prompts[5]);
        let b = f.context(&c.prompts[5]);
        assert_eq!(a, b);
        assert_eq!(a.len(), D_CTX);
        assert_eq!(a[D_CTX - 1], 1.0);
    }

    #[test]
    fn roughly_whitened() {
        let c = Corpus::build(2);
        let f = SimFeaturizer::new(2);
        let xs = f.contexts(&c.prompts[..2000]);
        for j in 0..D_CTX - 1 {
            let mean = xs.iter().map(|x| x[j]).sum::<f64>() / xs.len() as f64;
            let var =
                xs.iter().map(|x| (x[j] - mean).powi(2)).sum::<f64>() / xs.len() as f64;
            assert!(mean.abs() < 0.6, "dim {j} mean {mean}");
            assert!(var > 0.2 && var < 2.2, "dim {j} var {var}");
        }
    }

    #[test]
    fn family_clusters_are_linearly_separable_enough() {
        // same-family contexts must be closer than cross-family on average
        let c = Corpus::build(3);
        let f = SimFeaturizer::new(3);
        let fam = |b: usize| -> Vec<Vec<f64>> {
            c.prompts
                .iter()
                .filter(|p| p.bench == b)
                .take(40)
                .map(|p| f.context(p))
                .collect()
        };
        let a = fam(0);
        let b = fam(4);
        let dist = |x: &[f64], y: &[f64]| -> f64 {
            x.iter()
                .zip(y)
                .map(|(u, v)| (u - v) * (u - v))
                .sum::<f64>()
                .sqrt()
        };
        let within: f64 = (0..20).map(|i| dist(&a[i], &a[i + 20])).sum::<f64>() / 20.0;
        let across: f64 = (0..20).map(|i| dist(&a[i], &b[i])).sum::<f64>() / 20.0;
        assert!(within < across, "within {within} across {across}");
    }
}
