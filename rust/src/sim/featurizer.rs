//! Pure-Rust surrogate featurizer.
//!
//! The *production* context path is the AOT-lowered JAX/Pallas featurizer
//! executed via PJRT (`runtime::Embedder`); experiments use it when
//! artifacts are present (cached context matrix).  This surrogate exists as
//! the artifact-free fallback so `cargo test` and the experiment harness
//! work in isolation: it produces whitened 26-d contexts with the same
//! information content (benchmark-family clusters + prompt length), which
//! is exactly what the real embedding exposes to the bandit.

use super::corpus::{Prompt, BENCHMARKS, N_BENCH};
use crate::util::rng::{mix2, Rng};

pub const D_CTX: usize = 26;

/// Deterministic whitened featurizer.
pub struct SimFeaturizer {
    /// per-benchmark cluster centroids in the 25 non-bias dims
    centroids: Vec<[f64; D_CTX - 1]>,
    /// direction carrying prompt-length information
    len_dir: [f64; D_CTX - 1],
    seed: u64,
}

impl SimFeaturizer {
    pub fn new(seed: u64) -> SimFeaturizer {
        let mut rng = Rng::new(mix2(seed, 0xFEA7));
        let mut centroids = Vec::with_capacity(N_BENCH);
        for _ in 0..N_BENCH {
            let mut c = [0.0; D_CTX - 1];
            for v in &mut c {
                *v = 0.80 * rng.normal();
            }
            centroids.push(c);
        }
        // demean across families so the context distribution is centered
        // (the real PCA featurizer centers by construction)
        for j in 0..D_CTX - 1 {
            let mean: f64 = centroids.iter().map(|c| c[j]).sum::<f64>() / N_BENCH as f64;
            for c in &mut centroids {
                c[j] -= mean;
            }
        }
        let mut len_dir = [0.0; D_CTX - 1];
        for v in &mut len_dir {
            *v = rng.normal() / ((D_CTX - 1) as f64).sqrt();
        }
        SimFeaturizer {
            centroids,
            len_dir,
            seed,
        }
    }

    /// Whitened 26-d context (unit-ish variance dims + trailing bias 1).
    pub fn context(&self, p: &Prompt) -> Vec<f64> {
        let (_, _, lo, hi, _) = BENCHMARKS[p.bench];
        let len_z = (p.n_words as f64 - (lo + hi) as f64 / 2.0) / ((hi - lo) as f64 / 3.46);
        let mut rng = Rng::new(mix2(self.seed ^ 0xC0, p.id as u64));
        let mut x = Vec::with_capacity(D_CTX);
        let c = &self.centroids[p.bench];
        for j in 0..D_CTX - 1 {
            x.push(c[j] + 0.30 * len_z * self.len_dir[j] + 0.55 * rng.normal());
        }
        x.push(1.0);
        x
    }

    /// Contexts for a whole prompt set.
    pub fn contexts(&self, prompts: &[Prompt]) -> Vec<Vec<f64>> {
        prompts.iter().map(|p| self.context(p)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::corpus::Corpus;

    #[test]
    fn deterministic_and_bias_terminated() {
        let c = Corpus::build(1);
        let f = SimFeaturizer::new(1);
        let a = f.context(&c.prompts[5]);
        let b = f.context(&c.prompts[5]);
        assert_eq!(a, b);
        assert_eq!(a.len(), D_CTX);
        assert_eq!(a[D_CTX - 1], 1.0);
    }

    #[test]
    fn roughly_whitened() {
        let c = Corpus::build(2);
        let f = SimFeaturizer::new(2);
        let xs = f.contexts(&c.prompts[..2000]);
        for j in 0..D_CTX - 1 {
            let mean = xs.iter().map(|x| x[j]).sum::<f64>() / xs.len() as f64;
            let var =
                xs.iter().map(|x| (x[j] - mean).powi(2)).sum::<f64>() / xs.len() as f64;
            assert!(mean.abs() < 0.6, "dim {j} mean {mean}");
            assert!(var > 0.2 && var < 2.2, "dim {j} var {var}");
        }
    }

    #[test]
    fn family_clusters_are_linearly_separable_enough() {
        // same-family contexts must be closer than cross-family on average
        let c = Corpus::build(3);
        let f = SimFeaturizer::new(3);
        let fam = |b: usize| -> Vec<Vec<f64>> {
            c.prompts
                .iter()
                .filter(|p| p.bench == b)
                .take(40)
                .map(|p| f.context(p))
                .collect()
        };
        let a = fam(0);
        let b = fam(4);
        let dist = |x: &[f64], y: &[f64]| -> f64 {
            x.iter()
                .zip(y)
                .map(|(u, v)| (u - v) * (u - v))
                .sum::<f64>()
                .sqrt()
        };
        let within: f64 = (0..20).map(|i| dist(&a[i], &a[i + 20])).sum::<f64>() / 20.0;
        let across: f64 = (0..20).map(|i| dist(&a[i], &b[i])).sum::<f64>() / 20.0;
        assert!(within < across, "within {within} across {across}");
    }
}
