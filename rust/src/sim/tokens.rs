//! Tokenizer — Rust mirror of `python/compile/tokenizer.py`.
//!
//! MUST stay in lock-step with the python spec: the AOT-lowered embedding
//! graph consumes these token ids.  Known-answer vectors below are pinned
//! on both sides (see `python/tests/test_tokenizer.py`).

pub const VOCAB_SIZE: u32 = 8192;
pub const L_MAX: usize = 64;

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01B3;

/// FNV-1a 64-bit hash (wrapping multiply).
#[inline]
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Word -> vocab id in [1, VOCAB_SIZE). 0 is PAD.
#[inline]
pub fn word_id(word: &str) -> u32 {
    1 + (fnv1a64(word.as_bytes()) % (VOCAB_SIZE as u64 - 1)) as u32
}

/// Tokenize a prompt: lowercase, split on whitespace, hash, pad/truncate
/// to `L_MAX`.
pub fn tokenize(text: &str) -> [i32; L_MAX] {
    let lower = text.to_lowercase();
    let mut out = [0i32; L_MAX];
    for (i, w) in lower.split_whitespace().take(L_MAX).enumerate() {
        out[i] = word_id(w) as i32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors_match_python() {
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a64(b"hello"), 0xA430_D846_80AA_BD0B);
        assert_eq!(fnv1a64(b"w42"), 0x5F40_A719_48F9_E7DC);
    }

    #[test]
    fn word_id_known_vectors_match_python() {
        assert_eq!(word_id("w42"), 7488);
        assert_eq!(word_id("hello"), 8181);
        assert_eq!(word_id("mmlu_3"), 5975);
    }

    #[test]
    fn tokenize_pads_truncates_lowercases() {
        let t = tokenize("Hello W42");
        assert_eq!(t[0], 8181);
        assert_eq!(t[1], 7488);
        assert!(t[2..].iter().all(|&v| v == 0));
        let long: String = (0..200).map(|i| format!("w{i} ")).collect();
        let t2 = tokenize(&long);
        assert!(t2.iter().all(|&v| v != 0));
    }

    #[test]
    fn ids_in_range() {
        for w in ["a", "zzz", "mmlu_0", "gsm8k_119"] {
            let id = word_id(w);
            assert!(id >= 1 && id < VOCAB_SIZE);
        }
    }
}
