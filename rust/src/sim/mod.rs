//! Simulation substrates: corpus, world (reward/cost matrices), judges,
//! drift views, tokenizer parity and the surrogate featurizer.

pub mod corpus;
pub mod featurizer;
pub mod tokens;
pub mod world;

pub use corpus::{Corpus, Prompt};
pub use featurizer::{hash_features, SimFeaturizer};
pub use world::{
    model_bank, EnvView, FlashScenario, Judge, ModelSpec, World, FLASH, GEMINI_PRO, JUDGES, LLAMA,
    MISTRAL,
};
