//! Counterfactual replay: drive any registered builder policy through a
//! captured decision log under the `serve --shadow` scoring rules.
//!
//! Each shard's host is rebuilt exactly as `serve` built it (policy
//! spec, d, seed, starting portfolio with priors — all in the segment
//! header), coupled to one shared budget ledger, and the merged record
//! stream is applied in global capture order.  Matched decisions absorb
//! the realised feedback; diverging ones are charged declared prices
//! (see [`crate::server::ServerState`]'s shadow scoring).  Replaying the
//! captured policy over a cold capture reproduces its decision sequence
//! bit-identically as long as no merge cycle folded queued rewards
//! *between* logged sync barriers — `tests/replay_conformance.rs`
//! asserts this end to end; `docs/replay.md` spells out the caveats.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::pacer::{PacerConfig, SharedPacer};
use crate::router::{build_policy, BuildCtx, FeedbackEvent, ModelSpec, PolicyHost};
use crate::util::json::Json;

use super::record::{AdminOp, CaptureMeta, Record};
use super::segment::CapturedLog;

/// One replayed decision that differed from the served one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Divergence {
    pub shard: u32,
    pub seq: u64,
    /// slot the capture served
    pub served: u32,
    /// slot the replayed policy picked
    pub replayed: u32,
}

/// How many divergences are kept verbatim in the report.
const MAX_DIVERGENCE_SAMPLES: usize = 8;

/// Replay result for one policy spec.
pub struct PolicyReplay {
    /// the `name[:arg]` spec that was replayed
    pub spec: String,
    /// decisions replayed
    pub decisions: u64,
    /// feedback records scored against a replayed decision
    pub scored: u64,
    /// scored records where the replayed arm matched the served arm
    pub matched: u64,
    /// realised reward absorbed on matched decisions
    pub reward_matched: f64,
    /// estimated spend: realised cost on matches, declared prices on
    /// divergences (the shadow-scoring rule)
    pub est_spend: f64,
    /// final dual λ after the replay
    pub lambda: f64,
    /// replayed decisions that diverged from the served arm
    pub diverged: u64,
    /// first few divergences, for diagnostics
    pub divergences: Vec<Divergence>,
    /// decisions whose recorded λ differed (at the bit level) from the
    /// replayed λ — 0 means the pacer trajectory was reproduced exactly
    pub lambda_drift: u64,
    /// the capture hit a snapshot restore; replay stopped there
    pub hit_restore: bool,
    /// the fitted per-shard hosts (prior export, further inspection)
    hosts: Vec<(u32, PolicyHost)>,
}

impl PolicyReplay {
    /// Stable summary document (the conformance goldens compare these).
    pub fn to_json(&self) -> Json {
        let match_rate = if self.scored > 0 {
            self.matched as f64 / self.scored as f64
        } else {
            0.0
        };
        let mean_reward = if self.matched > 0 {
            self.reward_matched / self.matched as f64
        } else {
            0.0
        };
        let est_mean_cost = if self.scored > 0 {
            self.est_spend / self.scored as f64
        } else {
            0.0
        };
        Json::obj(vec![
            ("policy", Json::Str(self.spec.clone())),
            ("decisions", Json::Num(self.decisions as f64)),
            ("scored", Json::Num(self.scored as f64)),
            ("matched", Json::Num(self.matched as f64)),
            ("match_rate", Json::Num(match_rate)),
            ("mean_reward_matched", Json::Num(mean_reward)),
            ("est_spend", Json::Num(self.est_spend)),
            ("est_mean_cost", Json::Num(est_mean_cost)),
            ("lambda", Json::Num(self.lambda)),
            ("diverged", Json::Num(self.diverged as f64)),
            ("lambda_drift", Json::Num(self.lambda_drift as f64)),
            ("hit_restore", Json::Bool(self.hit_restore)),
        ])
    }
}

/// A routed-but-not-yet-scored request during replay.
struct PendingReplay {
    /// slot the capture served
    served: u32,
    /// slot the replayed policy picked
    replayed: usize,
    /// declared blended price of the served slot at decision time (from
    /// the decision record's eligible table)
    served_blended: f64,
    x: Vec<f64>,
}

/// Rebuild one shard's host the way `serve` built it.  Cold captures
/// (fresh portfolio in the header, priors included) rebuild
/// bit-identically; warm captures (`serve --restore`) only recover the
/// slot layout via [`PolicyHost::sync_portfolio`] — their learned state
/// is gone, so decision-level identity is not expected.
fn build_host(spec: &str, meta: &CaptureMeta, budget: Option<f64>) -> Result<PolicyHost, String> {
    let cold = !meta.warm && meta.models.iter().all(|m| m.is_some());
    if cold {
        let models: Vec<ModelSpec> = meta
            .models
            .iter()
            .flatten()
            .map(|m| {
                let spec = ModelSpec::new(&m.name, m.price_in, m.price_out);
                match m.prior {
                    Some((n_eff, r0)) => spec.with_prior(n_eff, r0),
                    None => spec,
                }
            })
            .collect();
        return build_policy(
            spec,
            &BuildCtx {
                d: meta.d as usize,
                budget,
                seed: meta.seed,
                models: &models,
            },
        );
    }
    let mut host = build_policy(
        spec,
        &BuildCtx {
            d: meta.d as usize,
            budget,
            seed: meta.seed,
            models: &[],
        },
    )?;
    let slots: Vec<Option<(String, f64, f64)>> = meta
        .models
        .iter()
        .map(|m| {
            m.as_ref()
                .map(|mm| (mm.name.clone(), mm.price_in, mm.price_out))
        })
        .collect();
    host.sync_portfolio(&slots);
    Ok(host)
}

/// Drive `spec` through the captured log counterfactually.
pub fn replay_policy(log: &CapturedLog, spec: &str) -> Result<PolicyReplay, String> {
    let first_meta = log
        .shards
        .values()
        .next()
        .map(|s| &s.meta)
        .ok_or("replay: empty capture")?;
    let budget = first_meta.budget;
    // one deployment-wide ledger, exactly as `serve` couples its shards
    let ledger = budget.map(|b| Arc::new(SharedPacer::new(PacerConfig::new(b))));
    let mut hosts: BTreeMap<u32, PolicyHost> = BTreeMap::new();
    for (shard, stream) in &log.shards {
        let mut host = build_host(spec, &stream.meta, budget)?;
        if let Some(l) = &ledger {
            host.use_shared_pacer(l.clone());
        }
        hosts.insert(*shard, host);
    }

    let mut rep = PolicyReplay {
        spec: spec.to_string(),
        decisions: 0,
        scored: 0,
        matched: 0,
        reward_matched: 0.0,
        est_spend: 0.0,
        lambda: 0.0,
        diverged: 0,
        divergences: Vec::new(),
        lambda_drift: 0,
        hit_restore: false,
        hosts: Vec::new(),
    };
    let mut pending: HashMap<(u32, u64), PendingReplay> = HashMap::new();
    let mut queued: BTreeMap<u32, Vec<FeedbackEvent>> = BTreeMap::new();

    'stream: for (shard, rec) in log.global_order() {
        let Some(host) = hosts.get_mut(&shard) else {
            continue;
        };
        match rec {
            Record::Header(_) => {}
            Record::Decision(d) => {
                let rd = host.route(&d.x);
                rep.decisions += 1;
                if rd.lambda.to_bits() != d.lambda.to_bits() {
                    rep.lambda_drift += 1;
                }
                if rd.arm as u64 != d.arm as u64 {
                    rep.diverged += 1;
                    if rep.divergences.len() < MAX_DIVERGENCE_SAMPLES {
                        rep.divergences.push(Divergence {
                            shard,
                            seq: d.seq,
                            served: d.arm,
                            replayed: rd.arm as u32,
                        });
                    }
                }
                let served_blended = d
                    .eligible
                    .iter()
                    .find(|e| e.slot == d.arm)
                    .map(|e| e.blended)
                    .unwrap_or(0.0);
                pending.insert(
                    (shard, d.request_id),
                    PendingReplay {
                        served: d.arm,
                        replayed: rd.arm,
                        served_blended,
                        x: d.x.clone(),
                    },
                );
            }
            Record::Feedback(f) => {
                let Some(p) = pending.remove(&(shard, f.request_id)) else {
                    continue;
                };
                rep.scored += 1;
                if p.replayed as u64 == p.served as u64 {
                    // matched: absorb the realised feedback, exactly as
                    // the serving path did (queued rewards fold at the
                    // logged sync barrier)
                    rep.matched += 1;
                    rep.reward_matched += f.reward;
                    rep.est_spend += f.cost;
                    if f.queued {
                        host.observe_cost(f.cost);
                        queued.entry(shard).or_default().push(FeedbackEvent {
                            arm: p.replayed,
                            context: p.x,
                            reward: f.reward,
                        });
                    } else {
                        host.feedback(p.replayed, &p.x, f.reward, f.cost);
                    }
                } else {
                    // diverged: charge declared prices — realised cost
                    // scaled by the price ratio when both sides are
                    // known, raw blended price otherwise
                    let replayed_blended = host
                        .registry()
                        .get(p.replayed)
                        .map_or(0.0, |e| e.blended_per_1k);
                    let est = if p.served_blended > 0.0 && f.cost > 0.0 {
                        f.cost * replayed_blended / p.served_blended
                    } else {
                        replayed_blended
                    };
                    rep.est_spend += est;
                    host.observe_cost(est);
                }
            }
            Record::Admin(a) => match &a.op {
                AdminOp::AddModel {
                    name,
                    price_in,
                    price_out,
                    prior,
                } => {
                    if host.try_add_model(name, *price_in, *price_out, *prior).is_none() {
                        host.add_model(name, *price_in, *price_out, *prior);
                    }
                }
                AdminOp::DeleteModel { slot } => {
                    host.delete_model(*slot as usize);
                }
                AdminOp::Reprice {
                    slot,
                    price_in,
                    price_out,
                } => {
                    host.reprice(*slot as usize, *price_in, *price_out);
                }
                AdminOp::SetBudget { budget } => {
                    host.set_budget(*budget);
                }
                AdminOp::SyncBarrier => {
                    if let Some(events) = queued.get_mut(&shard) {
                        host.apply_update_batch(events);
                        events.clear();
                    }
                }
                AdminOp::Restore => {
                    // the capture's learned state was replaced wholesale;
                    // a counterfactual replay cannot follow it
                    rep.hit_restore = true;
                    break 'stream;
                }
            },
        }
    }
    // rewards still queued when the capture ended (no trailing barrier)
    for (shard, events) in &queued {
        if events.is_empty() {
            continue;
        }
        if let Some(host) = hosts.get_mut(shard) {
            host.apply_update_batch(events);
        }
    }
    rep.lambda = hosts.values().next().map_or(0.0, |h| h.lambda());
    rep.hosts = hosts.into_iter().collect();
    Ok(rep)
}

/// Fold the fitted per-shard posteriors into one snapshot — the same
/// merge the engine's cycle performs (first shard's replica absorbs
/// every other shard's delta, then adopts the global) — and export it as
/// a `(policy kind, state)` pair ready for
/// [`crate::scenario::snapshot::save_value`] and `serve --restore`.
pub fn export_priors(rep: &mut PolicyReplay) -> Result<(String, Json), String> {
    let mut it = rep.hosts.iter_mut();
    let Some((_, first)) = it.next() else {
        return Err("export-priors: replay produced no hosts".to_string());
    };
    if let Some(mut global) = first.export_arms() {
        for (_, h) in it {
            let Some(arms) = h.export_arms() else { continue };
            for (g, o) in global.iter_mut().zip(arms.iter()) {
                if let (Some(g), Some(o)) = (g.as_mut(), o.as_ref()) {
                    g.merge(o, 1.0);
                }
            }
        }
        first.adopt_arms(&global);
    }
    Ok((first.kind().to_string(), first.export_state()))
}
