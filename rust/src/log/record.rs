//! Log record payloads — the bytes inside one crc-guarded frame.
//!
//! Layout is little-endian throughout: a one-byte record tag, then the
//! fields in declaration order.  Strings are u32-length-prefixed UTF-8,
//! vectors u32-count-prefixed, `Option` fields a one-byte presence flag,
//! f64 as raw IEEE bits (bit-exact round-trip — replay compares λ at the
//! bit level).  [`Record::encode`] / [`Record::decode`] round-trip
//! exactly (property-tested in `tests/decision_log.rs`);
//! [`encode_decision_into`] / [`encode_feedback_into`] emit the same
//! bytes straight from borrowed slices for the writer's allocation-free
//! append path (byte equivalence asserted below).

const TAG_HEADER: u8 = 0;
const TAG_DECISION: u8 = 1;
const TAG_FEEDBACK: u8 = 2;
const TAG_ADMIN: u8 = 3;

const OP_ADD_MODEL: u8 = 0;
const OP_DELETE_MODEL: u8 = 1;
const OP_REPRICE: u8 = 2;
const OP_SET_BUDGET: u8 = 3;
const OP_RESTORE: u8 = 4;
const OP_SYNC_BARRIER: u8 = 5;

/// One initial-portfolio entry in a segment header (`None` in the
/// slot-aligned list marks a tombstoned slot of a warm capture).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    pub price_in: f64,
    pub price_out: f64,
    /// optional `(n_eff, r0)` heuristic prior
    pub prior: Option<(f64, f64)>,
}

/// Segment header: everything replay needs to rebuild this shard's host
/// exactly as `serve` built it (policy spec, dimensionality, seed,
/// budget, slot-aligned starting portfolio).
#[derive(Clone, Debug, PartialEq)]
pub struct CaptureMeta {
    pub shard: u32,
    /// context dimensionality
    pub d: u32,
    /// the shard host's RNG seed
    pub seed: u64,
    /// $/request budget (`None` = unbudgeted)
    pub budget: Option<f64>,
    /// builder spec string (`name[:arg]`) the capture served
    pub policy: String,
    /// capture started from `serve --restore`: the slot layout below is
    /// the restored portfolio (prior-less) and an exact cold rebuild —
    /// hence bit-identical replay — is not possible
    pub warm: bool,
    /// slot-aligned starting portfolio; `None` = tombstoned slot
    pub models: Vec<Option<ModelMeta>>,
}

/// One slot of the eligible set at decision time, with the declared
/// prices the host advertised for it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EligibleSlot {
    pub slot: u32,
    /// declared blended $/1k-token price
    pub blended: f64,
    /// frozen c̃ cost snapshot
    pub c_tilde: f64,
}

/// One routing decision.
#[derive(Clone, Debug, PartialEq)]
pub struct DecisionRec {
    /// global capture sequence number (process-wide append clock)
    pub seq: u64,
    /// host step clock observed after the decision (informational —
    /// replay derives its own clock)
    pub t: u64,
    pub request_id: u64,
    /// pacer dual λ the decision was taken under
    pub lambda: f64,
    /// served slot id
    pub arm: u32,
    /// decision was forced (burn-in / circuit breaker)
    pub forced: bool,
    /// eligible-set size the policy reported
    pub n_eligible: u32,
    /// request features
    pub x: Vec<f64>,
    /// host-advisory eligible set with declared prices
    pub eligible: Vec<EligibleSlot>,
}

/// Realised feedback for one served decision.
#[derive(Clone, Debug, PartialEq)]
pub struct FeedbackRec {
    pub seq: u64,
    pub request_id: u64,
    /// slot id the feedback settled on (the served arm)
    pub arm: u32,
    pub reward: f64,
    pub cost: f64,
    /// the serving shard queued the reward for its merge cycle (sharded
    /// mode) instead of applying it immediately
    pub queued: bool,
}

/// One admin-plane event, logged by every shard it was applied to.
#[derive(Clone, Debug, PartialEq)]
pub enum AdminOp {
    AddModel {
        name: String,
        price_in: f64,
        price_out: f64,
        prior: Option<(f64, f64)>,
    },
    DeleteModel {
        slot: u32,
    },
    Reprice {
        slot: u32,
        price_in: f64,
        price_out: f64,
    },
    SetBudget {
        budget: f64,
    },
    /// a snapshot restore replaced this shard's learned state; replay
    /// cannot follow it and stops here
    Restore,
    /// queued rewards folded into the posterior (merge cycle / sync);
    /// replay mirrors the fold at the same point in the stream
    SyncBarrier,
}

/// [`AdminOp`] plus its place on the capture clock.
#[derive(Clone, Debug, PartialEq)]
pub struct AdminRec {
    pub seq: u64,
    pub op: AdminOp,
}

/// One log record (a decoded frame payload).
#[derive(Clone, Debug, PartialEq)]
pub enum Record {
    Header(CaptureMeta),
    Decision(DecisionRec),
    Feedback(FeedbackRec),
    Admin(AdminRec),
}

impl Record {
    /// Global capture sequence (0 for headers, which sit outside the
    /// record stream).
    pub fn seq(&self) -> u64 {
        match self {
            Record::Header(_) => 0,
            Record::Decision(d) => d.seq,
            Record::Feedback(f) => f.seq,
            Record::Admin(a) => a.seq,
        }
    }

    /// Append this record's payload bytes to `buf`.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Record::Header(m) => encode_header(buf, m),
            Record::Decision(d) => {
                buf.push(TAG_DECISION);
                put_u64(buf, d.seq);
                put_u64(buf, d.t);
                put_u64(buf, d.request_id);
                put_f64(buf, d.lambda);
                put_u32(buf, d.arm);
                put_bool(buf, d.forced);
                put_u32(buf, d.n_eligible);
                put_u32(buf, d.x.len() as u32);
                for &v in &d.x {
                    put_f64(buf, v);
                }
                put_u32(buf, d.eligible.len() as u32);
                for e in &d.eligible {
                    put_u32(buf, e.slot);
                    put_f64(buf, e.blended);
                    put_f64(buf, e.c_tilde);
                }
            }
            Record::Feedback(f) => {
                encode_feedback_into(buf, f.seq, f.request_id, f.arm, f.reward, f.cost, f.queued)
            }
            Record::Admin(a) => {
                buf.push(TAG_ADMIN);
                put_u64(buf, a.seq);
                match &a.op {
                    AdminOp::AddModel {
                        name,
                        price_in,
                        price_out,
                        prior,
                    } => {
                        buf.push(OP_ADD_MODEL);
                        put_str(buf, name);
                        put_f64(buf, *price_in);
                        put_f64(buf, *price_out);
                        put_opt_pair(buf, *prior);
                    }
                    AdminOp::DeleteModel { slot } => {
                        buf.push(OP_DELETE_MODEL);
                        put_u32(buf, *slot);
                    }
                    AdminOp::Reprice {
                        slot,
                        price_in,
                        price_out,
                    } => {
                        buf.push(OP_REPRICE);
                        put_u32(buf, *slot);
                        put_f64(buf, *price_in);
                        put_f64(buf, *price_out);
                    }
                    AdminOp::SetBudget { budget } => {
                        buf.push(OP_SET_BUDGET);
                        put_f64(buf, *budget);
                    }
                    AdminOp::Restore => buf.push(OP_RESTORE),
                    AdminOp::SyncBarrier => buf.push(OP_SYNC_BARRIER),
                }
            }
        }
    }

    /// Decode one frame payload.  The whole payload must be consumed —
    /// trailing bytes mean a layout mismatch and are rejected.
    pub fn decode(payload: &[u8]) -> Result<Record, String> {
        let mut c = Cursor::new(payload);
        let rec = match c.u8()? {
            TAG_HEADER => Record::Header(decode_header(&mut c)?),
            TAG_DECISION => {
                let seq = c.u64()?;
                let t = c.u64()?;
                let request_id = c.u64()?;
                let lambda = c.f64()?;
                let arm = c.u32()?;
                let forced = c.boolean()?;
                let n_eligible = c.u32()?;
                let nx = c.u32()? as usize;
                let mut x = Vec::new();
                for _ in 0..nx {
                    x.push(c.f64()?);
                }
                let ne = c.u32()? as usize;
                let mut eligible = Vec::new();
                for _ in 0..ne {
                    eligible.push(EligibleSlot {
                        slot: c.u32()?,
                        blended: c.f64()?,
                        c_tilde: c.f64()?,
                    });
                }
                Record::Decision(DecisionRec {
                    seq,
                    t,
                    request_id,
                    lambda,
                    arm,
                    forced,
                    n_eligible,
                    x,
                    eligible,
                })
            }
            TAG_FEEDBACK => Record::Feedback(FeedbackRec {
                seq: c.u64()?,
                request_id: c.u64()?,
                arm: c.u32()?,
                reward: c.f64()?,
                cost: c.f64()?,
                queued: c.boolean()?,
            }),
            TAG_ADMIN => {
                let seq = c.u64()?;
                let op = match c.u8()? {
                    OP_ADD_MODEL => AdminOp::AddModel {
                        name: c.string()?,
                        price_in: c.f64()?,
                        price_out: c.f64()?,
                        prior: c.opt_pair()?,
                    },
                    OP_DELETE_MODEL => AdminOp::DeleteModel { slot: c.u32()? },
                    OP_REPRICE => AdminOp::Reprice {
                        slot: c.u32()?,
                        price_in: c.f64()?,
                        price_out: c.f64()?,
                    },
                    OP_SET_BUDGET => AdminOp::SetBudget { budget: c.f64()? },
                    OP_RESTORE => AdminOp::Restore,
                    OP_SYNC_BARRIER => AdminOp::SyncBarrier,
                    other => return Err(format!("record: unknown admin op tag {other}")),
                };
                Record::Admin(AdminRec { seq, op })
            }
            other => return Err(format!("record: unknown record tag {other}")),
        };
        c.finish()?;
        Ok(rec)
    }
}

fn encode_header(buf: &mut Vec<u8>, m: &CaptureMeta) {
    buf.push(TAG_HEADER);
    put_u32(buf, m.shard);
    put_u32(buf, m.d);
    put_u64(buf, m.seed);
    match m.budget {
        Some(b) => {
            put_bool(buf, true);
            put_f64(buf, b);
        }
        None => put_bool(buf, false),
    }
    put_str(buf, &m.policy);
    put_bool(buf, m.warm);
    put_u32(buf, m.models.len() as u32);
    for slot in &m.models {
        match slot {
            Some(mm) => {
                put_bool(buf, true);
                put_str(buf, &mm.name);
                put_f64(buf, mm.price_in);
                put_f64(buf, mm.price_out);
                put_opt_pair(buf, mm.prior);
            }
            None => put_bool(buf, false),
        }
    }
}

fn decode_header(c: &mut Cursor) -> Result<CaptureMeta, String> {
    let shard = c.u32()?;
    let d = c.u32()?;
    let seed = c.u64()?;
    let budget = if c.boolean()? { Some(c.f64()?) } else { None };
    let policy = c.string()?;
    let warm = c.boolean()?;
    let n = c.u32()? as usize;
    let mut models = Vec::new();
    for _ in 0..n {
        if !c.boolean()? {
            models.push(None);
            continue;
        }
        let name = c.string()?;
        let price_in = c.f64()?;
        let price_out = c.f64()?;
        let prior = c.opt_pair()?;
        models.push(Some(ModelMeta {
            name,
            price_in,
            price_out,
            prior,
        }));
    }
    Ok(CaptureMeta {
        shard,
        d,
        seed,
        budget,
        policy,
        warm,
        models,
    })
}

/// Encode a decision payload straight from borrowed slices — the
/// writer's hot path.  Byte-identical to encoding the equivalent
/// [`Record::Decision`] (asserted below): the eligible table pairs each
/// slot id with the slot-aligned declared prices, 0.0 past either
/// price slice's end (retired slots carry 0.0 there anyway).
// lint: no_alloc
#[allow(clippy::too_many_arguments)]
pub(crate) fn encode_decision_into(
    buf: &mut Vec<u8>,
    seq: u64,
    t: u64,
    request_id: u64,
    lambda: f64,
    arm: u32,
    forced: bool,
    n_eligible: u32,
    x: &[f64],
    eligible: &[usize],
    blended: &[f64],
    c_tilde: &[f64],
) {
    buf.push(TAG_DECISION);
    put_u64(buf, seq);
    put_u64(buf, t);
    put_u64(buf, request_id);
    put_f64(buf, lambda);
    put_u32(buf, arm);
    put_bool(buf, forced);
    put_u32(buf, n_eligible);
    put_u32(buf, x.len() as u32);
    for &v in x {
        put_f64(buf, v);
    }
    put_u32(buf, eligible.len() as u32);
    for &slot in eligible {
        put_u32(buf, slot as u32);
        put_f64(buf, blended.get(slot).copied().unwrap_or(0.0));
        put_f64(buf, c_tilde.get(slot).copied().unwrap_or(0.0));
    }
}

/// Encode a feedback payload (hot path; byte-identical to the
/// equivalent [`Record::Feedback`]).
// lint: no_alloc
pub(crate) fn encode_feedback_into(
    buf: &mut Vec<u8>,
    seq: u64,
    request_id: u64,
    arm: u32,
    reward: f64,
    cost: f64,
    queued: bool,
) {
    buf.push(TAG_FEEDBACK);
    put_u64(buf, seq);
    put_u64(buf, request_id);
    put_u32(buf, arm);
    put_f64(buf, reward);
    put_f64(buf, cost);
    put_bool(buf, queued);
}

// ----------------------------------------------------------------------
// primitive writers (push/extend only — safe inside no_alloc spans once
// the target buffer's capacity has warmed up)

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_bool(buf: &mut Vec<u8>, v: bool) {
    buf.push(v as u8);
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_opt_pair(buf: &mut Vec<u8>, v: Option<(f64, f64)>) {
    match v {
        Some((a, b)) => {
            put_bool(buf, true);
            put_f64(buf, a);
            put_f64(buf, b);
        }
        None => put_bool(buf, false),
    }
}

// ----------------------------------------------------------------------
// primitive reader

/// Bounds-checked little-endian reader over one frame payload.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        match self.b.get(self.pos..self.pos.saturating_add(n)) {
            Some(s) => {
                self.pos += n;
                Ok(s)
            }
            None => Err(format!(
                "record: payload short — wanted {n} bytes at offset {} of {}",
                self.pos,
                self.b.len()
            )),
        }
    }

    fn u8(&mut self) -> Result<u8, String> {
        self.take(1)?
            .first()
            .copied()
            .ok_or_else(|| "record: empty payload".to_string())
    }

    fn boolean(&mut self) -> Result<bool, String> {
        Ok(self.u8()? != 0)
    }

    fn u32(&mut self) -> Result<u32, String> {
        let a: [u8; 4] = self
            .take(4)?
            .try_into()
            .map_err(|_| "record: bad u32".to_string())?;
        Ok(u32::from_le_bytes(a))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let a: [u8; 8] = self
            .take(8)?
            .try_into()
            .map_err(|_| "record: bad u64".to_string())?;
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| "record: invalid utf-8".to_string())
    }

    fn opt_pair(&mut self) -> Result<Option<(f64, f64)>, String> {
        if self.boolean()? {
            Ok(Some((self.f64()?, self.f64()?)))
        } else {
            Ok(None)
        }
    }

    fn finish(&self) -> Result<(), String> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(format!(
                "record: {} trailing bytes after a complete record",
                self.b.len() - self.pos
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta() -> CaptureMeta {
        CaptureMeta {
            shard: 3,
            d: 6,
            seed: 45,
            budget: Some(6.6e-4),
            policy: "epsilon:0.2".into(),
            warm: false,
            models: vec![
                Some(ModelMeta {
                    name: "llama-3.1-8b".into(),
                    price_in: 0.10,
                    price_out: 0.10,
                    prior: Some((25.0, 0.7)),
                }),
                None,
                Some(ModelMeta {
                    name: "gemini-2.5-pro".into(),
                    price_in: 1.25,
                    price_out: 10.0,
                    prior: None,
                }),
            ],
        }
    }

    #[test]
    fn every_record_kind_roundtrips() {
        let records = vec![
            Record::Header(sample_meta()),
            Record::Decision(DecisionRec {
                seq: 17,
                t: 4,
                request_id: 99,
                lambda: 0.125,
                arm: 2,
                forced: true,
                n_eligible: 3,
                x: vec![0.5, -1.0, f64::MIN_POSITIVE],
                eligible: vec![
                    EligibleSlot {
                        slot: 0,
                        blended: 0.1,
                        c_tilde: 2.9e-5,
                    },
                    EligibleSlot {
                        slot: 2,
                        blended: 5.625,
                        c_tilde: 1.5e-2,
                    },
                ],
            }),
            Record::Feedback(FeedbackRec {
                seq: 18,
                request_id: 99,
                arm: 2,
                reward: 0.875,
                cost: 1.5e-2,
                queued: true,
            }),
            Record::Admin(AdminRec {
                seq: 19,
                op: AdminOp::AddModel {
                    name: "flash".into(),
                    price_in: 0.3,
                    price_out: 2.5,
                    prior: Some((20.0, 0.5)),
                },
            }),
            Record::Admin(AdminRec {
                seq: 20,
                op: AdminOp::Reprice {
                    slot: 1,
                    price_in: 0.2,
                    price_out: 0.8,
                },
            }),
            Record::Admin(AdminRec {
                seq: 21,
                op: AdminOp::DeleteModel { slot: 3 },
            }),
            Record::Admin(AdminRec {
                seq: 22,
                op: AdminOp::SetBudget { budget: 1e-3 },
            }),
            Record::Admin(AdminRec {
                seq: 23,
                op: AdminOp::Restore,
            }),
            Record::Admin(AdminRec {
                seq: 24,
                op: AdminOp::SyncBarrier,
            }),
        ];
        for r in records {
            let mut buf = Vec::new();
            r.encode(&mut buf);
            assert_eq!(Record::decode(&buf).unwrap(), r, "roundtrip of {r:?}");
        }
    }

    #[test]
    fn slice_encoders_match_struct_encoding() {
        let blended = [0.1, 0.0, 5.625];
        let c_tilde = [2.9e-5, 0.0, 1.5e-2];
        let eligible = [0usize, 2usize];
        let x = [0.25, -0.5, 3.0];
        let mut fast = Vec::new();
        encode_decision_into(&mut fast, 7, 3, 42, 0.5, 2, false, 2, &x, &eligible, &blended, &c_tilde);
        let rec = Record::Decision(DecisionRec {
            seq: 7,
            t: 3,
            request_id: 42,
            lambda: 0.5,
            arm: 2,
            forced: false,
            n_eligible: 2,
            x: x.to_vec(),
            eligible: eligible
                .iter()
                .map(|&s| EligibleSlot {
                    slot: s as u32,
                    blended: blended[s],
                    c_tilde: c_tilde[s],
                })
                .collect(),
        });
        let mut slow = Vec::new();
        rec.encode(&mut slow);
        assert_eq!(fast, slow, "decision slice encoder drifted from Record::encode");

        let mut fast = Vec::new();
        encode_feedback_into(&mut fast, 8, 42, 2, 0.9, 1e-4, true);
        let rec = Record::Feedback(FeedbackRec {
            seq: 8,
            request_id: 42,
            arm: 2,
            reward: 0.9,
            cost: 1e-4,
            queued: true,
        });
        let mut slow = Vec::new();
        rec.encode(&mut slow);
        assert_eq!(fast, slow, "feedback slice encoder drifted from Record::encode");
    }

    #[test]
    fn decode_rejects_malformed_payloads() {
        // unknown tag
        assert!(Record::decode(&[9]).is_err());
        // truncated payload
        let mut buf = Vec::new();
        Record::Header(sample_meta()).encode(&mut buf);
        assert!(Record::decode(&buf[..buf.len() - 1]).is_err());
        // trailing garbage
        buf.push(0);
        assert!(Record::decode(&buf).unwrap_err().contains("trailing"));
        // empty
        assert!(Record::decode(&[]).is_err());
    }
}
