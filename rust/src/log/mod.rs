//! Per-shard append-only decision logs + deterministic offline replay.
//!
//! Capture (`serve --log-dir DIR`): every worker shard appends its
//! routing decisions, realised feedback and admin events to its own
//! segment files as compact crc-guarded binary frames, stamped from one
//! process-wide sequence clock so the cross-shard arrival order that
//! drove the shared budget ledger is recoverable.  The append path is
//! allocation-free after warmup (asserted by `tests/alloc_probe.rs`) and
//! never panics or perturbs serving — a failed append only bumps the
//! `log_errors` metric.
//!
//! Replay (`paretobandit replay --log-dir DIR`): [`replay_policy`] drives
//! any registered [`crate::router::PolicyBuilder`] policy through a
//! captured log counterfactually under the same scoring rules as
//! `serve --shadow` — matched decisions absorb the realised feedback,
//! diverging ones are charged declared prices — and [`export_priors`]
//! folds the fitted per-shard posteriors into one snapshot loadable via
//! `serve --restore`.  Record schema, rotation and the replay workflow
//! are documented in `docs/replay.md`.

mod record;
mod replay;
mod segment;

pub use record::{
    AdminOp, AdminRec, CaptureMeta, DecisionRec, EligibleSlot, FeedbackRec, ModelMeta, Record,
};
pub use replay::{export_priors, replay_policy, Divergence, PolicyReplay};
pub use segment::{
    read_log_dir, read_segment, CapturedLog, LogWriter, SegmentRead, ShardStream,
    DEFAULT_SEGMENT_BYTES,
};
