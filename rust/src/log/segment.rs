//! Segment files: crc-guarded frames, rotation, truncated-tail-tolerant
//! reads and the (shard, seq) merge across a capture directory.
//!
//! A segment is `[u32 payload len][u32 crc32][payload]` frames back to
//! back, first frame always a [`Record::Header`] (rotation re-stamps it,
//! so every segment is self-describing).  Filenames are
//! `shardNNN-segNNNNN.pblog`, chosen so a lexicographic directory sort
//! is the (shard, segment) order.  On read, a tail cut mid-frame (crash)
//! ends the segment cleanly with `truncated` set; a crc mismatch stops
//! it with `corrupt` set — frames before the damage are always kept.

use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::{self, BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::record::{self, AdminOp, AdminRec, CaptureMeta, Record};

/// Default rotation threshold (bytes per segment).
pub const DEFAULT_SEGMENT_BYTES: u64 = 64 * 1024 * 1024;

/// Frame overhead: u32 payload length + u32 crc32.
const FRAME_OVERHEAD: u64 = 8;

// lint: allow(index) reason="const-eval table build; i < 256 by the loop bound"
const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = build_crc_table();

/// CRC-32 (IEEE, the zlib polynomial) over `bytes`.
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = u32::MAX;
    for &b in bytes {
        let idx = ((crc ^ b as u32) & 0xff) as usize;
        // the mask keeps idx < 256, so the lookup always hits
        crc = CRC_TABLE.get(idx).copied().unwrap_or(0) ^ (crc >> 8);
    }
    !crc
}

fn segment_path(dir: &Path, shard: u32, seg: u32) -> PathBuf {
    dir.join(format!("shard{shard:03}-seg{seg:05}.pblog"))
}

/// Append-only writer for one shard's segment stream.
///
/// Sequence numbers come from a process-wide clock shared by every
/// shard's writer, so the cross-shard order costs hit the shared budget
/// ledger in is recoverable from the merged log (exact under
/// synchronous clients; see `docs/replay.md` for the concurrency
/// caveat).  The writer never panics: every fallible call returns
/// `io::Result` and the serving layer routes failures to a metric.
pub struct LogWriter {
    dir: PathBuf,
    meta: CaptureMeta,
    out: BufWriter<File>,
    seg_index: u32,
    seg_bytes: u64,
    max_seg_bytes: u64,
    clock: Arc<AtomicU64>,
    /// reused frame-staging buffer (capacity settles after warmup, so
    /// the append path allocates nothing)
    scratch: Vec<u8>,
}

impl LogWriter {
    /// Create a writer with its own private sequence clock (single-shard
    /// captures, tests).
    pub fn create(dir: &Path, meta: CaptureMeta, max_seg_bytes: u64) -> Result<LogWriter, String> {
        LogWriter::with_clock(dir, meta, max_seg_bytes, Arc::new(AtomicU64::new(0)))
    }

    /// Create a writer stamping sequence numbers from a shared clock
    /// (one clock per capture, cloned into every shard's writer).
    /// Refuses to overwrite an existing segment — use a fresh directory
    /// per capture.
    pub fn with_clock(
        dir: &Path,
        meta: CaptureMeta,
        max_seg_bytes: u64,
        clock: Arc<AtomicU64>,
    ) -> Result<LogWriter, String> {
        fs::create_dir_all(dir).map_err(|e| format!("log: create {}: {e}", dir.display()))?;
        let path = segment_path(dir, meta.shard, 0);
        let file = OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&path)
            .map_err(|e| format!("log: create {}: {e}", path.display()))?;
        let mut w = LogWriter {
            dir: dir.to_path_buf(),
            meta,
            out: BufWriter::new(file),
            seg_index: 0,
            seg_bytes: 0,
            max_seg_bytes: max_seg_bytes.max(4096),
            clock,
            scratch: Vec::with_capacity(1024),
        };
        w.append_header()
            .map_err(|e| format!("log: {}: header: {e}", path.display()))?;
        Ok(w)
    }

    /// The shard this writer captures.
    pub fn shard(&self) -> u32 {
        self.meta.shard
    }

    fn next_seq(&self) -> u64 {
        // AcqRel: the ticket order must agree with the real order of the
        // surrounding ledger operations on every shard thread
        self.clock.fetch_add(1, Ordering::AcqRel)
    }

    fn append_header(&mut self) -> io::Result<()> {
        self.scratch.clear();
        Record::Header(self.meta.clone()).encode(&mut self.scratch);
        self.write_frame()
    }

    /// Stage `scratch` as one `[len][crc][payload]` frame.
    fn write_frame(&mut self) -> io::Result<()> {
        let len = self.scratch.len() as u32;
        let crc = crc32(&self.scratch);
        self.out.write_all(&len.to_le_bytes())?;
        self.out.write_all(&crc.to_le_bytes())?;
        self.out.write_all(&self.scratch)?;
        self.seg_bytes += FRAME_OVERHEAD + self.scratch.len() as u64;
        Ok(())
    }

    /// Rotate to a fresh segment once the current one crosses the
    /// threshold (cold path: opens a file and re-stamps the header).
    fn maybe_rotate(&mut self) -> io::Result<()> {
        if self.seg_bytes < self.max_seg_bytes {
            return Ok(());
        }
        self.rotate()
    }

    fn rotate(&mut self) -> io::Result<()> {
        self.out.flush()?;
        self.seg_index += 1;
        let path = segment_path(&self.dir, self.meta.shard, self.seg_index);
        let file = OpenOptions::new().write(true).create_new(true).open(path)?;
        self.out = BufWriter::new(file);
        self.seg_bytes = 0;
        self.append_header()
    }

    /// Append one routing decision; returns its global sequence number.
    /// Steady-state this allocates nothing: the frame is staged in the
    /// reused scratch buffer and written through the fixed-size
    /// `BufWriter` (asserted by `tests/alloc_probe.rs`); rotation — the
    /// only allocating step — runs in [`LogWriter::rotate`] once per
    /// `max_seg_bytes`.
    // lint: no_alloc
    #[allow(clippy::too_many_arguments)]
    pub fn append_decision(
        &mut self,
        t: u64,
        request_id: u64,
        lambda: f64,
        arm: u32,
        forced: bool,
        n_eligible: u32,
        x: &[f64],
        eligible: &[usize],
        blended: &[f64],
        c_tilde: &[f64],
    ) -> io::Result<u64> {
        let seq = self.next_seq();
        self.scratch.clear();
        record::encode_decision_into(
            &mut self.scratch,
            seq,
            t,
            request_id,
            lambda,
            arm,
            forced,
            n_eligible,
            x,
            eligible,
            blended,
            c_tilde,
        );
        self.write_frame()?;
        self.maybe_rotate()?;
        Ok(seq)
    }

    /// Append one realised-feedback record (allocation-free like
    /// [`LogWriter::append_decision`]).
    // lint: no_alloc
    pub fn append_feedback(
        &mut self,
        request_id: u64,
        arm: u32,
        reward: f64,
        cost: f64,
        queued: bool,
    ) -> io::Result<u64> {
        let seq = self.next_seq();
        self.scratch.clear();
        record::encode_feedback_into(&mut self.scratch, seq, request_id, arm, reward, cost, queued);
        self.write_frame()?;
        self.maybe_rotate()?;
        Ok(seq)
    }

    /// Append one admin-plane event (cold path).
    pub fn append_admin(&mut self, op: &AdminOp) -> io::Result<u64> {
        let seq = self.next_seq();
        self.scratch.clear();
        Record::Admin(AdminRec {
            seq,
            op: op.clone(),
        })
        .encode(&mut self.scratch);
        self.write_frame()?;
        self.maybe_rotate()?;
        Ok(seq)
    }

    /// Flush buffered frames to the OS (merge cycles, shutdown).
    pub fn flush(&mut self) -> io::Result<()> {
        self.out.flush()
    }
}

impl Drop for LogWriter {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

// ----------------------------------------------------------------------
// reading

/// One segment file, decoded.
pub struct SegmentRead {
    /// the header frame (`None` only when the file lost its very first
    /// frame — such a segment carries no records either)
    pub meta: Option<CaptureMeta>,
    /// decoded records (headers excluded), in file order
    pub records: Vec<Record>,
    /// the file ended mid-frame (crash truncation); records above are
    /// the intact prefix
    pub truncated: bool,
    /// a crc-mismatched or undecodable frame stopped the read; records
    /// above are the intact prefix
    pub corrupt: bool,
}

fn le_u32(b: &[u8]) -> Option<u32> {
    let a: [u8; 4] = b.get(..4)?.try_into().ok()?;
    Some(u32::from_le_bytes(a))
}

/// Decode one segment file, tolerating a truncated tail.
pub fn read_segment(path: &Path) -> Result<SegmentRead, String> {
    let bytes = fs::read(path).map_err(|e| format!("log: read {}: {e}", path.display()))?;
    let mut out = SegmentRead {
        meta: None,
        records: Vec::new(),
        truncated: false,
        corrupt: false,
    };
    let mut pos = 0usize;
    loop {
        let Some(head) = bytes.get(pos..pos + 8) else {
            // clean end exactly at a frame boundary; anything shorter is
            // a partial frame header left by a crash
            out.truncated = pos < bytes.len();
            break;
        };
        let (len, crc) = match (le_u32(head), le_u32(head.get(4..).unwrap_or(&[]))) {
            (Some(l), Some(c)) => (l as usize, c),
            _ => {
                out.truncated = true;
                break;
            }
        };
        let Some(payload) = bytes.get(pos + 8..pos + 8 + len) else {
            out.truncated = true;
            break;
        };
        if crc32(payload) != crc {
            out.corrupt = true;
            break;
        }
        match Record::decode(payload) {
            Ok(Record::Header(m)) => {
                if out.meta.is_none() {
                    out.meta = Some(m);
                }
            }
            Ok(r) => out.records.push(r),
            Err(_) => {
                out.corrupt = true;
                break;
            }
        }
        pos += 8 + len;
    }
    Ok(out)
}

/// One shard's record stream, merged across its segments.
pub struct ShardStream {
    pub meta: CaptureMeta,
    /// records ordered by sequence number
    pub records: Vec<Record>,
    pub truncated: bool,
    pub corrupt: bool,
}

/// A capture directory, decoded and merged.
pub struct CapturedLog {
    /// shard id → its stream (BTreeMap: deterministic shard order)
    pub shards: BTreeMap<u32, ShardStream>,
}

impl CapturedLog {
    /// All records merged on (shard, seq) — the canonical listing order.
    pub fn merged(&self) -> Vec<(u32, &Record)> {
        let mut out = Vec::new();
        for (shard, stream) in &self.shards {
            for r in &stream.records {
                out.push((*shard, r));
            }
        }
        out
    }

    /// All records in global capture order: the shared append clock's
    /// ticket order, ties (impossible under one clock) broken by shard.
    pub fn global_order(&self) -> Vec<(u32, &Record)> {
        let mut out = self.merged();
        out.sort_by_key(|(shard, r)| (r.seq(), *shard));
        out
    }

    /// Total record count (headers excluded).
    pub fn n_records(&self) -> usize {
        self.shards.values().map(|s| s.records.len()).sum()
    }

    /// Any shard stream flagged truncated or corrupt.
    pub fn damaged(&self) -> bool {
        self.shards.values().any(|s| s.truncated || s.corrupt)
    }
}

/// Read every `*.pblog` segment under `dir` and merge per shard.
/// Headerless segments (a crash before the first frame landed) carry no
/// records and are skipped.
pub fn read_log_dir(dir: &Path) -> Result<CapturedLog, String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("log: read dir {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("pblog"))
        .collect();
    // shardNNN-segNNNNN names: lexicographic == (shard, segment) order
    paths.sort();
    if paths.is_empty() {
        return Err(format!("log: no .pblog segments in {}", dir.display()));
    }
    let mut shards: BTreeMap<u32, ShardStream> = BTreeMap::new();
    for p in &paths {
        let seg = read_segment(p)?;
        let Some(meta) = seg.meta else { continue };
        let entry = shards.entry(meta.shard).or_insert_with(|| ShardStream {
            meta: meta.clone(),
            records: Vec::new(),
            truncated: false,
            corrupt: false,
        });
        entry.records.extend(seg.records);
        entry.truncated |= seg.truncated;
        entry.corrupt |= seg.corrupt;
    }
    if shards.is_empty() {
        return Err(format!(
            "log: {} has segments but none with a readable header",
            dir.display()
        ));
    }
    for s in shards.values_mut() {
        s.records.sort_by_key(|r| r.seq());
    }
    Ok(CapturedLog { shards })
}
