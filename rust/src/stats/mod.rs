//! Statistics toolkit backing the paper's evaluation: percentile bootstrap
//! CIs, exact sign / Fisher tests with Holm–Bonferroni correction, rank
//! correlations (Spearman ρ, Kendall τ_b and W), Wilson CIs and effect
//! sizes.  All deterministic given a seed.

mod boot;
mod rank;
mod tests;

pub use boot::{bootstrap_ci, bootstrap_ci_median, paired_bootstrap_ci, Ci};
pub use rank::{kendall_tau_b, kendall_w, spearman, wilson_ci};
pub use tests::{fisher_exact_2x2, holm_bonferroni, sign_test};

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Sample standard deviation (n-1).
pub fn std_dev_sample(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Percentile in [0,100] with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = (p / 100.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (idx - lo as f64) * (v[hi] - v[lo])
    }
}

/// Cohen's d between two samples (pooled sd).
pub fn cohens_d(a: &[f64], b: &[f64]) -> f64 {
    let (ma, mb) = (mean(a), mean(b));
    let (sa, sb) = (std_dev_sample(a), std_dev_sample(b));
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let pooled = (((na - 1.0) * sa * sa + (nb - 1.0) * sb * sb) / (na + nb - 2.0)).sqrt();
    (mb - ma) / pooled
}

/// Mean absolute deviation between paired samples.
pub fn mad_paired(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64
}

/// Pearson correlation.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (ma, mb) = (mean(a), mean(b));
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for i in 0..a.len() {
        let (da, db) = (a[i] - ma, b[i] - mb);
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    cov / (va.sqrt() * vb.sqrt())
}

#[cfg(test)]
mod base_tests {
    use super::*;

    #[test]
    fn moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn cohens_d_known() {
        // unit separation, unit sd -> d ≈ 1
        let a: Vec<f64> = (0..100).map(|i| (i % 10) as f64 / 3.0).collect();
        let b: Vec<f64> = a.iter().map(|x| x + 1.0).collect();
        assert!((cohens_d(&a, &b) - 1.0 / std_dev_sample(&a)).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 4.0, 6.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }
}
