//! Rank statistics: Spearman ρ, Kendall τ_b, Kendall W, Wilson CI
//! (Appendices B and E).

use super::pearson;

/// Average ranks (1-based) with ties averaged.
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            r[k] = avg;
        }
        i = j + 1;
    }
    r
}

/// Spearman rank correlation (ties averaged).
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    pearson(&ranks(a), &ranks(b))
}

/// Kendall τ_b (tie-corrected). O(n²) — fine at evaluation sizes.
pub fn kendall_tau_b(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let (mut conc, mut disc, mut tie_a, mut tie_b) = (0i64, 0i64, 0i64, 0i64);
    for i in 0..n {
        for j in (i + 1)..n {
            let da = a[i] - a[j];
            let db = b[i] - b[j];
            if da == 0.0 && db == 0.0 {
                // tied in both: excluded from all counts
            } else if da == 0.0 {
                tie_a += 1;
            } else if db == 0.0 {
                tie_b += 1;
            } else if (da > 0.0) == (db > 0.0) {
                conc += 1;
            } else {
                disc += 1;
            }
        }
    }
    let n0 = (n * (n - 1) / 2) as f64;
    let denom = ((n0 - tie_a as f64) * (n0 - tie_b as f64)).sqrt();
    if denom == 0.0 {
        return 0.0;
    }
    (conc - disc) as f64 / denom
}

/// Kendall's coefficient of concordance W for `m` raters over `n` items.
/// `scores[rater][item]`.  No tie correction (continuous scores).
pub fn kendall_w(scores: &[Vec<f64>]) -> f64 {
    let m = scores.len();
    assert!(m >= 2);
    let n = scores[0].len();
    assert!(n >= 2);
    let mut rank_sums = vec![0.0; n];
    for rater in scores {
        let r = ranks(rater);
        for i in 0..n {
            rank_sums[i] += r[i];
        }
    }
    let mean_r = rank_sums.iter().sum::<f64>() / n as f64;
    let s: f64 = rank_sums.iter().map(|r| (r - mean_r) * (r - mean_r)).sum();
    12.0 * s / (m as f64 * m as f64 * (n as f64 * n as f64 * n as f64 - n as f64))
}

/// 95% Wilson score interval for a proportion.
pub fn wilson_ci(successes: u64, n: u64) -> (f64, f64) {
    if n == 0 {
        return (0.0, 1.0);
    }
    let z = 1.959963984540054f64;
    let nf = n as f64;
    let p = successes as f64 / nf;
    let z2 = z * z;
    let denom = 1.0 + z2 / nf;
    let center = (p + z2 / (2.0 * nf)) / denom;
    let half = z * (p * (1.0 - p) / nf + z2 / (4.0 * nf * nf)).sqrt() / denom;
    ((center - half).max(0.0), (center + half).min(1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ranks_with_ties() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let a = [1.0, 2.0, 5.0, 9.0];
        let b = [2.0, 4.0, 26.0, 82.0]; // any monotone transform
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        let c = [4.0, 3.0, 2.0, 1.0];
        assert!((spearman(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_noise_calibration() {
        // x vs x+noise: ρ depends only on noise/signal ratio
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let y: Vec<f64> = x.iter().map(|v| v + rng.normal()).collect();
        let rho = spearman(&x, &y);
        // Pearson would be 1/sqrt(2) ≈ 0.707; Spearman slightly lower
        assert!((rho - 0.68).abs() < 0.04, "{rho}");
    }

    #[test]
    fn kendall_tau_known() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 3.0, 2.0, 4.0];
        // 5 concordant, 1 discordant -> tau = 4/6
        assert!((kendall_tau_b(&a, &b) - 4.0 / 6.0).abs() < 1e-12);
        assert!((kendall_tau_b(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_w_bounds() {
        // perfect agreement -> W = 1
        let scores = vec![
            vec![0.1, 0.5, 0.9],
            vec![0.2, 0.6, 0.8],
            vec![0.15, 0.55, 0.95],
        ];
        assert!((kendall_w(&scores) - 1.0).abs() < 1e-12);
        // systematic disagreement -> small W
        let scores = vec![
            vec![1.0, 2.0, 3.0],
            vec![3.0, 1.0, 2.0],
            vec![2.0, 3.0, 1.0],
        ];
        assert!(kendall_w(&scores) < 0.05);
    }

    #[test]
    fn wilson_known_values() {
        // 100% of 1766 (Appendix B): CI ≈ [99.8, 100.0]%
        let (lo, hi) = wilson_ci(1766, 1766);
        assert!(lo > 0.997 && hi == 1.0, "({lo}, {hi})");
        // 79.7% of 1766: CI ≈ [77.7, 81.5]%
        let (lo, hi) = wilson_ci((0.797f64 * 1766.0).round() as u64, 1766);
        assert!((lo - 0.777).abs() < 0.004 && (hi - 0.815).abs() < 0.004, "({lo}, {hi})");
    }
}
