//! Percentile bootstrap confidence intervals (the paper's default: 95%
//! percentile bootstrap, up to 10,000 resamples, seed-level resampling).

use super::{mean, median};
use crate::util::rng::Rng;

/// A point estimate with a (lo, hi) confidence interval.
#[derive(Clone, Copy, Debug)]
pub struct Ci {
    pub est: f64,
    pub lo: f64,
    pub hi: f64,
}

impl Ci {
    pub fn contains(&self, v: f64) -> bool {
        v >= self.lo && v <= self.hi
    }

    pub fn excludes_zero(&self) -> bool {
        !self.contains(0.0)
    }
}

fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    let idx = (p / 100.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (idx - lo as f64) * (v[hi] - v[lo])
    }
}

fn bootstrap_stat<F: Fn(&[f64]) -> f64>(
    xs: &[f64],
    b: usize,
    seed: u64,
    conf: f64,
    stat: F,
) -> Ci {
    assert!(!xs.is_empty());
    let mut rng = Rng::new(seed);
    let mut stats = Vec::with_capacity(b);
    let mut resample = vec![0.0; xs.len()];
    for _ in 0..b {
        for r in resample.iter_mut() {
            *r = xs[rng.below(xs.len())];
        }
        stats.push(stat(&resample));
    }
    stats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = (1.0 - conf) / 2.0 * 100.0;
    Ci {
        est: stat(xs),
        lo: percentile_sorted(&stats, alpha),
        hi: percentile_sorted(&stats, 100.0 - alpha),
    }
}

/// 95% percentile-bootstrap CI of the mean.
pub fn bootstrap_ci(xs: &[f64], b: usize, seed: u64) -> Ci {
    bootstrap_stat(xs, b, seed, 0.95, mean)
}

/// 95% percentile-bootstrap CI of the median (resamples the median
/// directly — appropriate for heavy-tailed regret distributions, App. D).
pub fn bootstrap_ci_median(xs: &[f64], b: usize, seed: u64) -> Ci {
    bootstrap_stat(xs, b, seed, 0.95, median)
}

/// Bootstrap CI for the mean of paired differences `a[i] - b[i]`, with
/// optional Bonferroni widening for `m` simultaneous comparisons
/// (confidence 1 - 0.05/m).
pub fn paired_bootstrap_ci(a: &[f64], b: &[f64], boots: usize, seed: u64, m: usize) -> Ci {
    assert_eq!(a.len(), b.len());
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let conf = 1.0 - 0.05 / m.max(1) as f64;
    bootstrap_stat(&diffs, boots, seed, conf, mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ci_brackets_true_mean() {
        let mut rng = Rng::new(1);
        let xs: Vec<f64> = (0..200).map(|_| 5.0 + rng.normal()).collect();
        let ci = bootstrap_ci(&xs, 2000, 2);
        assert!(ci.lo < 5.0 + 0.3 && ci.hi > 5.0 - 0.3, "{ci:?}");
        assert!(ci.lo < ci.est && ci.est < ci.hi);
    }

    #[test]
    fn ci_narrows_with_n() {
        let mut rng = Rng::new(3);
        let small: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let large: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let cs = bootstrap_ci(&small, 1000, 4);
        let cl = bootstrap_ci(&large, 1000, 4);
        assert!(cl.hi - cl.lo < cs.hi - cs.lo);
    }

    #[test]
    fn deterministic_per_seed() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let a = bootstrap_ci(&xs, 500, 7);
        let b = bootstrap_ci(&xs, 500, 7);
        assert_eq!((a.lo, a.hi), (b.lo, b.hi));
    }

    #[test]
    fn paired_detects_shift_and_bonferroni_widens() {
        let mut rng = Rng::new(5);
        let a: Vec<f64> = (0..100).map(|_| rng.normal() + 1.0).collect();
        let b: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let ci1 = paired_bootstrap_ci(&a, &b, 2000, 6, 1);
        let ci4 = paired_bootstrap_ci(&a, &b, 2000, 6, 4);
        assert!(ci1.excludes_zero(), "{ci1:?}");
        assert!(ci4.hi - ci4.lo > ci1.hi - ci1.lo, "Bonferroni must widen");
    }

    #[test]
    fn median_ci_robust_to_outliers() {
        let mut xs: Vec<f64> = (0..99).map(|i| i as f64 / 99.0).collect();
        xs.push(1e6);
        let ci = bootstrap_ci_median(&xs, 1000, 8);
        assert!(ci.est < 1.0 && ci.hi < 2.0, "{ci:?}");
    }
}
