//! Exact hypothesis tests + multiplicity correction (Appendices C–D).

/// ln n! via lgamma-style Stirling series (exact enough for p-values).
fn ln_factorial(n: u64) -> f64 {
    if n < 2 {
        return 0.0;
    }
    // Stirling with correction terms; exact table for small n
    const TABLE: [f64; 21] = [
        0.0,
        0.0,
        0.6931471805599453,
        1.791759469228055,
        3.1780538303479458,
        4.787491742782046,
        6.579251212010101,
        8.525161361065415,
        10.60460290274525,
        12.801827480081469,
        15.104412573075516,
        17.502307845873887,
        19.987214495661885,
        22.552163853123425,
        25.19122118273868,
        27.89927138384089,
        30.671860106080672,
        33.50507345013689,
        36.39544520803305,
        39.339884187199495,
        42.335616460753485,
    ];
    if n <= 20 {
        return TABLE[n as usize];
    }
    let x = n as f64;
    x * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI * x).ln() + 1.0 / (12.0 * x)
        - 1.0 / (360.0 * x * x * x)
}

fn ln_choose(n: u64, k: u64) -> f64 {
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

/// Exact two-sided binomial sign test: `wins` successes out of `n`
/// informative pairs under H0: p = 0.5.  Returns the p-value.
pub fn sign_test(wins: u64, n: u64) -> f64 {
    if n == 0 {
        return 1.0;
    }
    let ln_half_n = n as f64 * 0.5f64.ln();
    let pmf = |k: u64| (ln_choose(n, k) + ln_half_n).exp();
    let k_lo = wins.min(n - wins);
    // two-sided: double the smaller tail (standard exact sign test)
    let tail: f64 = (0..=k_lo).map(pmf).sum();
    (2.0 * tail).min(1.0)
}

/// Fisher exact test (two-sided, hypergeometric) on the 2x2 table
/// [[a, b], [c, d]].  Two-sided by summing all tables with probability
/// ≤ the observed table's.
pub fn fisher_exact_2x2(a: u64, b: u64, c: u64, d: u64) -> f64 {
    let row1 = a + b;
    let row2 = c + d;
    let col1 = a + c;
    let n = row1 + row2;
    if n == 0 {
        return 1.0;
    }
    let ln_denom = ln_choose(n, col1);
    let p_of = |x: u64| -> f64 {
        // table (x, row1-x, col1-x, ...) valid iff bounds hold
        (ln_choose(row1, x) + ln_choose(row2, col1 - x) - ln_denom).exp()
    };
    let x_min = col1.saturating_sub(row2);
    let x_max = col1.min(row1);
    let p_obs = p_of(a);
    let mut total = 0.0;
    for x in x_min..=x_max {
        let p = p_of(x);
        if p <= p_obs * (1.0 + 1e-9) {
            total += p;
        }
    }
    total.min(1.0)
}

/// Holm–Bonferroni step-down correction.  Input raw p-values; output
/// adjusted p-values in the same order (monotone, capped at 1).
pub fn holm_bonferroni(ps: &[f64]) -> Vec<f64> {
    let m = ps.len();
    let mut idx: Vec<usize> = (0..m).collect();
    idx.sort_by(|&i, &j| ps[i].partial_cmp(&ps[j]).unwrap());
    let mut adj = vec![0.0; m];
    let mut running_max = 0.0f64;
    for (rank, &i) in idx.iter().enumerate() {
        let factor = (m - rank) as f64;
        let p = (ps[i] * factor).min(1.0);
        running_max = running_max.max(p);
        adj[i] = running_max;
    }
    adj
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_factorial_exact_vs_stirling_seam() {
        // continuity across the table/Stirling boundary
        let direct: f64 = (1..=25u64).map(|i| (i as f64).ln()).sum();
        assert!((ln_factorial(25) - direct).abs() < 1e-9);
    }

    #[test]
    fn sign_test_known_values() {
        // 20/20 wins: p = 2 * 0.5^20 ≈ 1.9e-6  (paper: p < 1e-5 at 20 seeds)
        let p = sign_test(20, 20);
        assert!((p - 2.0 * 0.5f64.powi(20)).abs() < 1e-12);
        // 17/20 wins: p ≈ 0.00258 (binom two-sided)
        let p = sign_test(17, 20);
        assert!((p - 0.002577).abs() < 1e-5, "{p}");
        // 10/20: p = 1
        assert!(sign_test(10, 20) > 0.99);
        // symmetric
        assert!((sign_test(3, 20) - sign_test(17, 20)).abs() < 1e-12);
    }

    #[test]
    fn fisher_known_values() {
        // classic tea-tasting 3/1/1/3: p = 0.4857...
        let p = fisher_exact_2x2(3, 1, 1, 3);
        assert!((p - 0.485714).abs() < 1e-5, "{p}");
        // strong association
        let p = fisher_exact_2x2(10, 0, 0, 10);
        assert!(p < 1.1e-5, "{p}");
        // no association
        assert!(fisher_exact_2x2(5, 5, 5, 5) > 0.99);
        // paper's App C table: warmup 0/20 vs TR 2/20 catastrophic -> n.s.
        let p = fisher_exact_2x2(0, 20, 2, 18);
        assert!(p > 0.4, "{p}");
    }

    #[test]
    fn holm_adjustment_monotone_and_bounded() {
        let raw = [0.01, 0.04, 0.03, 0.005];
        let adj = holm_bonferroni(&raw);
        // smallest raw p gets the full m multiplier
        assert!((adj[3] - 0.02).abs() < 1e-12);
        for (r, a) in raw.iter().zip(&adj) {
            assert!(a >= r);
            assert!(*a <= 1.0);
        }
        // order preserved under adjustment (monotone)
        let mut idx: Vec<usize> = (0..4).collect();
        idx.sort_by(|&i, &j| raw[i].partial_cmp(&raw[j]).unwrap());
        for w in idx.windows(2) {
            assert!(adj[w[0]] <= adj[w[1]] + 1e-12);
        }
    }

    #[test]
    fn holm_all_significant_when_tiny() {
        let adj = holm_bonferroni(&[1e-6, 1e-7, 1e-8]);
        assert!(adj.iter().all(|&p| p < 0.001));
    }
}
