//! The rule catalog for `pallas-lint`.
//!
//! Four repo-specific rule families (see `docs/analysis.md` for the
//! operator-facing catalog):
//!
//! * `panic` / `index` — panic-freedom in the request-serving call graph
//!   (`server/`, `router/`, `pacer/`, `client.rs`): no `.unwrap()` /
//!   `.expect(` / `panic!` / `unreachable!` / `todo!` / `unimplemented!`,
//!   and no slice indexing without `get` (reported under the separate
//!   `index` id so suppressions stay narrow).  Errors must flow through
//!   the `proto.rs` error codes instead.
//! * `atomics` — every `Ordering::*` site in the designated lock-free
//!   files (`pacer/shared.rs`, `server/metrics.rs`, `server/engine.rs`)
//!   must carry a one-line `invariant:` comment; any `Relaxed`/`SeqCst`
//!   outside those files is flagged.
//! * `no_alloc` — functions marked `// lint: no_alloc` may not contain
//!   allocating calls; this statically complements the runtime
//!   counting-allocator probe in `tests/alloc_probe.rs`.
//! * `proto` — wire-protocol exhaustiveness: every verb parsed in
//!   `server/proto.rs` needs an `api.rs` dispatch arm, a `ParetoClient`
//!   method, and a README protocol-table row; every error code must be
//!   constructed outside `proto.rs` and documented in the README.
//!
//! Plus `suppression` hygiene: an allow marker without a `reason="..."`
//! clause is itself a finding (and suppresses nothing).

use super::scan::{allow_markers, allow_rules, FileScan};

/// One lint finding.  `line` is 1-based for human output.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// Rule ids, in the order findings are grouped for display.
pub const RULES: &[&str] = &["panic", "index", "atomics", "no_alloc", "proto", "suppression"];

const PANIC_TOKENS: &[(&str, &str)] = &[
    (".unwrap()", "unwrap() on the serving path"),
    (".expect(", "expect() on the serving path"),
    ("panic!", "panic! on the serving path"),
    ("unreachable!", "unreachable! on the serving path"),
    ("todo!", "todo! on the serving path"),
    ("unimplemented!", "unimplemented! on the serving path"),
];

const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "vec!",
    ".to_vec(",
    ".clone(",
    "format!",
    "Box::new",
    "String::from",
    "String::new",
    ".to_string(",
    ".to_owned(",
    "with_capacity(",
    ".collect(",
];

/// Files whose atomics must each carry an `invariant:` comment.
const ATOMIC_FILES: &[&str] = &[
    "rust/src/pacer/shared.rs",
    "rust/src/server/metrics.rs",
    "rust/src/server/engine.rs",
    "rust/src/server/reactor.rs",
];

/// Is this path in the request-serving call graph (panic-freedom scope)?
fn serving_scope(path: &str) -> bool {
    path.starts_with("rust/src/server/")
        || path.starts_with("rust/src/router/")
        || path.starts_with("rust/src/pacer/")
        || path.starts_with("rust/src/log/")
        || path.starts_with("rust/src/deploy/")
        || path == "rust/src/client.rs"
}

/// Run the per-file rules (`panic`, `index`, `atomics`, `no_alloc`,
/// `suppression`) over one scanned file.
pub fn check_file(scan: &FileScan) -> Vec<Finding> {
    let mut out = Vec::new();
    let in_serving = serving_scope(&scan.path);
    let atomic_file = ATOMIC_FILES.contains(&scan.path.as_str());
    for (i, line) in scan.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();

        // suppression hygiene first: reason-less allows never suppress
        let markers = allow_markers(&line.comment);
        if markers > allow_rules(&line.comment, true).len() {
            out.push(Finding {
                file: scan.path.clone(),
                line: i + 1,
                rule: "suppression",
                msg: "lint: allow(...) is missing its reason=\"...\" clause".into(),
            });
        }

        if in_serving {
            for (tok, what) in PANIC_TOKENS {
                if find_token(code, tok) && !scan.allowed("panic", i) {
                    out.push(Finding {
                        file: scan.path.clone(),
                        line: i + 1,
                        rule: "panic",
                        msg: format!("{what} — return a proto.rs error code instead"),
                    });
                }
            }
            if has_direct_index(code) && !scan.allowed("index", i) {
                out.push(Finding {
                    file: scan.path.clone(),
                    line: i + 1,
                    rule: "index",
                    msg: "slice indexing without get() can panic on the serving path".into(),
                });
            }
        }

        if atomic_file {
            if code.contains("Ordering::") && !scan.has_invariant(i) && !scan.allowed("atomics", i)
            {
                out.push(Finding {
                    file: scan.path.clone(),
                    line: i + 1,
                    rule: "atomics",
                    msg: "atomic-ordering site lacks an invariant: comment".into(),
                });
            }
        } else if (code.contains("Ordering::Relaxed") || code.contains("Ordering::SeqCst"))
            && !scan.allowed("atomics", i)
        {
            out.push(Finding {
                file: scan.path.clone(),
                line: i + 1,
                rule: "atomics",
                msg: "Relaxed/SeqCst outside the annotated atomic files".into(),
            });
        }

        if let Some(f) = scan.no_alloc_span(i) {
            if i >= f.start {
                for tok in ALLOC_TOKENS {
                    if find_token(code, tok) && !scan.allowed("no_alloc", i) {
                        out.push(Finding {
                            file: scan.path.clone(),
                            line: i + 1,
                            rule: "no_alloc",
                            msg: format!("`{tok}` allocates inside no_alloc fn `{}`", f.name),
                        });
                    }
                }
            }
        }
    }
    out
}

/// `tok` occurs in `code`.  Tokens that start with an identifier char
/// (`panic!`, `vec!`, `Vec::new`) additionally require a non-identifier
/// char before the match, so `catch_panic!` does not match `panic!`;
/// method tokens (`.unwrap()`, `.to_vec(`) are naturally preceded by the
/// receiver and skip that check.
fn find_token(code: &str, tok: &str) -> bool {
    let ident_start = tok
        .chars()
        .next()
        .map(|c| c.is_ascii_alphanumeric() || c == '_')
        .unwrap_or(false);
    let mut from = 0;
    while let Some(b) = code[from..].find(tok) {
        let at = from + b;
        let before_ok = !ident_start
            || at == 0
            || code[..at]
                .chars()
                .last()
                .map(|c| !(c.is_ascii_alphanumeric() || c == '_'))
                .unwrap_or(true);
        if before_ok {
            return true;
        }
        from = at + tok.len();
    }
    false
}

/// Does the line index a slice/array directly (`xs[i]`)?  The heuristic:
/// `[` immediately preceded by an identifier character or a closing
/// bracket.  Type positions (`: [f64; 4]`), attributes (`#[...]`) and
/// macro brackets (`vec![`) are preceded by non-identifier chars and do
/// not match.
fn has_direct_index(code: &str) -> bool {
    let mut prev = ' ';
    for c in code.chars() {
        if c == '['
            && (prev.is_ascii_alphanumeric() || prev == '_' || prev == ')' || prev == ']')
        {
            return true;
        }
        prev = c;
    }
    false
}

// ----------------------------------------------------------------------
// wire-protocol exhaustiveness

/// `route_batch` -> `RouteBatch`
fn camel(verb: &str) -> String {
    verb.split('_')
        .map(|w| {
            let mut cs = w.chars();
            match cs.next() {
                Some(f) => f.to_ascii_uppercase().to_string() + cs.as_str(),
                None => String::new(),
            }
        })
        .collect()
}

/// Quoted string literals in `raw` (char-aligned with `code`), taken from
/// the span before the `=>` of a match arm.
fn arm_head_strings(raw: &str, code: &str) -> Vec<String> {
    let Some(arrow) = code.find("=>") else {
        return Vec::new();
    };
    // raw and code are char-aligned, so convert the byte offset in code
    // to a char count and slice raw by chars
    let nchars = code[..arrow].chars().count();
    let head: String = raw.chars().take(nchars).collect();
    let mut out = Vec::new();
    let mut rest = head.as_str();
    while let Some(q) = rest.find('"') {
        let tail = &rest[q + 1..];
        let Some(e) = tail.find('"') else { break };
        out.push(tail[..e].to_string());
        rest = &tail[e + 1..];
    }
    out
}

/// The protocol surface extracted from `server/proto.rs`.
pub struct ProtoSurface {
    /// verb -> 1-based line of its parse arm
    pub verbs: Vec<(String, usize)>,
    /// (variant, wire string, 1-based line)
    pub codes: Vec<(String, String, usize)>,
}

/// Extract verbs and error codes from the scanned `proto.rs`.
pub fn proto_surface(proto: &FileScan) -> ProtoSurface {
    let mut codes = Vec::new();
    for (i, line) in proto.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        // `ErrorCode::Variant => "wire_string"` (the as_str table)
        if let Some(p) = line.code.find("ErrorCode::") {
            let after = &line.code[p + "ErrorCode::".len()..];
            let variant: String = after
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !variant.is_empty() && after[variant.len()..].trim_start().starts_with("=>") {
                // the wire string is blanked in `code`; read it from raw
                let raw_tail: String = {
                    let nchars = line.code[..p].chars().count();
                    line.raw.chars().skip(nchars).collect()
                };
                if let Some(q) = raw_tail.find('"') {
                    let t = &raw_tail[q + 1..];
                    if let Some(e) = t.find('"') {
                        codes.push((variant, t[..e].to_string(), i + 1));
                    }
                }
            }
        }
    }
    let code_strings: Vec<&str> = codes.iter().map(|(_, s, _)| s.as_str()).collect();
    let mut verbs: Vec<(String, usize)> = Vec::new();
    for (i, line) in proto.lines.iter().enumerate() {
        if line.in_test || !line.code.trim_start().starts_with('"') {
            continue;
        }
        for s in arm_head_strings(&line.raw, &line.code) {
            if !code_strings.contains(&s.as_str()) && !verbs.iter().any(|(v, _)| *v == s) {
                verbs.push((s, i + 1));
            }
        }
    }
    ProtoSurface { verbs, codes }
}

/// Cross-file protocol exhaustiveness.  `scans` holds every scanned file
/// (including `proto.rs` itself); `readme` is the README text.
pub fn check_protocol(scans: &[FileScan], readme: &str) -> Vec<Finding> {
    let Some(proto) = scans.iter().find(|s| s.path.ends_with("server/proto.rs")) else {
        return Vec::new();
    };
    let api = scans.iter().find(|s| s.path.ends_with("server/api.rs"));
    let client = scans.iter().find(|s| s.path.ends_with("src/client.rs"));
    let surface = proto_surface(proto);
    let mut out = Vec::new();
    let non_test_contains = |s: &FileScan, needle: &str| {
        s.lines
            .iter()
            .any(|l| !l.in_test && l.code.contains(needle))
    };
    for (verb, line) in &surface.verbs {
        let variant = camel(verb);
        if let Some(api) = api {
            if !non_test_contains(api, &format!("Request::{variant}")) {
                out.push(Finding {
                    file: proto.path.clone(),
                    line: *line,
                    rule: "proto",
                    msg: format!("verb `{verb}` has no Request::{variant} dispatch arm in api.rs"),
                });
            }
        }
        if let Some(client) = client {
            if !non_test_contains(client, &format!("pub fn {verb}("))
                && !non_test_contains(client, &format!("pub fn {verb}<"))
            {
                out.push(Finding {
                    file: proto.path.clone(),
                    line: *line,
                    rule: "proto",
                    msg: format!("verb `{verb}` has no ParetoClient method `pub fn {verb}(...)`"),
                });
            }
        }
        if !readme.contains(&format!("| `{verb}`")) {
            out.push(Finding {
                file: proto.path.clone(),
                line: *line,
                rule: "proto",
                msg: format!("verb `{verb}` has no row in the README protocol table"),
            });
        }
    }
    for (variant, wire, line) in &surface.codes {
        let constructed = scans.iter().any(|s| {
            !s.path.ends_with("server/proto.rs")
                && non_test_contains(s, &format!("ErrorCode::{variant}"))
        });
        if !constructed {
            out.push(Finding {
                file: proto.path.clone(),
                line: *line,
                rule: "proto",
                msg: format!("error code `{wire}` (ErrorCode::{variant}) is never constructed"),
            });
        }
        if !readme.contains(&format!("`{wire}`")) {
            out.push(Finding {
                file: proto.path.clone(),
                line: *line,
                rule: "proto",
                msg: format!("error code `{wire}` is not documented in the README"),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::scan_source;

    #[test]
    fn panic_tokens_respect_boundaries() {
        assert!(find_token("x.unwrap();", ".unwrap()"));
        assert!(!find_token("x.unwrap_or(0);", ".unwrap()"));
        assert!(find_token("panic!(\"\")", "panic!"));
        assert!(!find_token("catch_panic!(x)", "panic!"));
    }

    #[test]
    fn direct_index_heuristic() {
        assert!(has_direct_index("let y = xs[i];"));
        assert!(has_direct_index("m.counts[idx].fetch_add(1, o);"));
        assert!(!has_direct_index("let a: [f64; 4] = b;"));
        assert!(!has_direct_index("#[derive(Clone)]"));
        assert!(!has_direct_index("let v = vec![0.0; n];"));
    }

    #[test]
    fn camel_maps_verbs() {
        assert_eq!(camel("route"), "Route");
        assert_eq!(camel("route_batch"), "RouteBatch");
        assert_eq!(camel("set_budget"), "SetBudget");
    }

    #[test]
    fn serving_scope_paths() {
        assert!(serving_scope("rust/src/server/api.rs"));
        assert!(serving_scope("rust/src/client.rs"));
        assert!(serving_scope("rust/src/log/segment.rs"));
        assert!(!serving_scope("rust/src/linalg/chol.rs"));
        assert!(!serving_scope("rust/src/analysis/rules.rs"));
    }

    #[test]
    fn atomics_rule_in_and_out_of_designated_files() {
        let designated = scan_source(
            "rust/src/pacer/shared.rs",
            "fn f(a: &AtomicU64) {\n    a.load(Ordering::Acquire);\n}\n",
        );
        let f = check_file(&designated);
        assert_eq!(f.len(), 1, "unannotated site flagged: {f:?}");
        assert_eq!(f[0].rule, "atomics");

        let annotated = scan_source(
            "rust/src/pacer/shared.rs",
            "fn f(a: &AtomicU64) {\n    // invariant: monotone counter, readers tolerate lag\n    a.load(Ordering::Relaxed);\n}\n",
        );
        assert!(check_file(&annotated).is_empty());

        let outside = scan_source(
            "rust/src/exp/run.rs",
            "fn f(a: &AtomicU64) {\n    a.load(Ordering::SeqCst);\n}\n",
        );
        let f = check_file(&outside);
        assert_eq!(f.len(), 1);
        assert!(f[0].msg.contains("outside"));
    }
}
