//! Finding aggregation, text/JSON rendering and the baseline ratchet.
//!
//! The baseline (`LINT_baseline.json`, committed at the repo root) maps
//! `"<file>::<rule>"` to an allowed finding count, mirroring the
//! `util::benchio` committed-JSON idiom: sorted keys, one entry per line,
//! so diffs review cleanly.  `--deny` fails when any key's current count
//! exceeds its baseline — existing debt can only ratchet down.

use std::collections::BTreeMap;

use super::rules::{Finding, RULES};
use crate::util::json::Json;

/// The outcome of a lint pass over the tree.
pub struct LintReport {
    pub findings: Vec<Finding>,
    /// files scanned (for the JSON report header)
    pub files_scanned: usize,
}

/// One baseline violation: a `<file>::<rule>` bucket over its allowance.
pub struct Violation {
    pub key: String,
    pub baseline: usize,
    pub current: usize,
}

impl LintReport {
    /// Finding counts keyed `"<file>::<rule>"` (the baseline schema).
    pub fn counts(&self) -> BTreeMap<String, usize> {
        let mut m = BTreeMap::new();
        for f in &self.findings {
            *m.entry(format!("{}::{}", f.file, f.rule)).or_insert(0) += 1;
        }
        m
    }

    /// Buckets whose current count exceeds the baseline allowance.
    pub fn violations(&self, baseline: &BTreeMap<String, usize>) -> Vec<Violation> {
        self.counts()
            .into_iter()
            .filter_map(|(key, current)| {
                let allowed = baseline.get(&key).copied().unwrap_or(0);
                (current > allowed).then_some(Violation {
                    key,
                    baseline: allowed,
                    current,
                })
            })
            .collect()
    }

    /// Machine-readable report (benchio-style: schema marker + entries).
    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("rule", Json::Str(f.rule.to_string())),
                    ("msg", Json::Str(f.msg.clone())),
                ])
            })
            .collect();
        let counts = Json::Obj(
            self.counts()
                .into_iter()
                .map(|(k, v)| (k, Json::Num(v as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::Str("pallas-lint/v1".into())),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            ("total", Json::Num(self.findings.len() as f64)),
            ("findings", Json::Arr(findings)),
            ("counts", counts),
        ])
    }

    /// Human-readable report.  With a baseline, per-bucket lines show
    /// current vs allowed and the summary separates new debt from known.
    pub fn render_text(&self, baseline: &BTreeMap<String, usize>) -> String {
        let mut out = String::new();
        let mut by_rule: BTreeMap<&str, usize> = BTreeMap::new();
        for f in &self.findings {
            *by_rule.entry(f.rule).or_insert(0) += 1;
        }
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.msg));
        }
        if !self.findings.is_empty() {
            out.push('\n');
        }
        out.push_str(&format!(
            "pallas-lint: {} finding(s) across {} file(s)",
            self.findings.len(),
            self.files_scanned
        ));
        let per_rule: Vec<String> = RULES
            .iter()
            .filter_map(|r| by_rule.get(r).map(|n| format!("{r}={n}")))
            .collect();
        if !per_rule.is_empty() {
            out.push_str(&format!(" ({})", per_rule.join(", ")));
        }
        out.push('\n');
        let viols = self.violations(baseline);
        if viols.is_empty() {
            out.push_str("baseline: clean (no bucket exceeds its allowance)\n");
        } else {
            for v in &viols {
                out.push_str(&format!(
                    "baseline EXCEEDED: {} has {} finding(s), allowance {}\n",
                    v.key, v.current, v.baseline
                ));
            }
        }
        out
    }
}

/// Load a baseline file.  A missing file is an empty baseline (zero
/// allowance everywhere), not an error.
pub fn load_baseline(path: &str) -> Result<BTreeMap<String, usize>, String> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(BTreeMap::new()),
        Err(e) => return Err(format!("read {path}: {e}")),
    };
    let j = Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?;
    let Json::Obj(m) = j else {
        return Err(format!("{path}: expected a JSON object"));
    };
    let mut out = BTreeMap::new();
    for (k, v) in m {
        let n = v
            .as_f64()
            .ok_or_else(|| format!("{path}: value for {k} is not a number"))?;
        out.insert(k, n as usize);
    }
    Ok(out)
}

/// Write a baseline: sorted keys, one entry per line (stable diffs).
pub fn write_baseline(path: &str, counts: &BTreeMap<String, usize>) -> Result<(), String> {
    let mut out = String::from("{\n");
    for (i, (k, v)) in counts.iter().enumerate() {
        out.push_str(&format!(
            "  {}: {}{}\n",
            Json::Str(k.clone()).to_string(),
            v,
            if i + 1 < counts.len() { "," } else { "" }
        ));
    }
    out.push_str("}\n");
    std::fs::write(path, out).map_err(|e| format!("write {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(findings: Vec<(&str, &'static str)>) -> LintReport {
        LintReport {
            findings: findings
                .into_iter()
                .map(|(file, rule)| Finding {
                    file: file.to_string(),
                    line: 1,
                    rule,
                    msg: "m".into(),
                })
                .collect(),
            files_scanned: 2,
        }
    }

    #[test]
    fn ratchet_blocks_new_debt_only() {
        let r = report(vec![("a.rs", "panic"), ("a.rs", "panic"), ("b.rs", "index")]);
        let mut base = BTreeMap::new();
        base.insert("a.rs::panic".to_string(), 2usize);
        base.insert("b.rs::index".to_string(), 1usize);
        assert!(r.violations(&base).is_empty(), "at allowance == clean");

        base.insert("a.rs::panic".to_string(), 1usize);
        let v = r.violations(&base);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].key, "a.rs::panic");
        assert_eq!(v[0].current, 2);
        assert_eq!(v[0].baseline, 1);
    }

    #[test]
    fn baseline_roundtrip() {
        let mut counts = BTreeMap::new();
        counts.insert("rust/src/x.rs::panic".to_string(), 3usize);
        counts.insert("rust/src/y.rs::index".to_string(), 1usize);
        let dir = std::env::temp_dir().join("pallas_lint_baseline_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("LINT_baseline.json");
        let path = path.to_str().unwrap();
        write_baseline(path, &counts).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.lines().count() >= 4, "one entry per line: {text}");
        let loaded = load_baseline(path).unwrap();
        assert_eq!(loaded, counts);
    }

    #[test]
    fn missing_baseline_is_empty() {
        let m = load_baseline("/nonexistent/LINT_baseline.json").unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn json_report_shape() {
        let r = report(vec![("a.rs", "panic")]);
        let j = r.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("pallas-lint/v1"));
        assert_eq!(j.get("total").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("findings").unwrap().idx(0).unwrap().get("rule").unwrap().as_str(),
            Some("panic")
        );
    }
}
