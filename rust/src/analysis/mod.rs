//! `pallas-lint`: the in-repo static analysis pass.
//!
//! A hand-rolled scanner + rule driver (no syn, no clippy plugins — the
//! build image is offline) that walks `rust/src/`, enforces the
//! repo-specific rules in [`rules`], and reports findings as text or
//! machine-readable JSON against the committed `LINT_baseline.json`
//! ratchet.  Run it as `paretobandit lint`; CI runs `lint --deny`.
//! The operator handbook is `docs/analysis.md`.

pub mod report;
pub mod rules;
pub mod scan;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub use report::{load_baseline, write_baseline, LintReport};
pub use rules::Finding;

/// Default baseline filename at the repo root.
pub const BASELINE_FILE: &str = "LINT_baseline.json";

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the tree rooted at `root` (the repo checkout: sources are read
/// from `<root>/rust/src`, the protocol table from `<root>/README.md`).
pub fn run_lint(root: &Path) -> Result<LintReport, String> {
    let src = root.join("rust").join("src");
    let mut files = Vec::new();
    rs_files(&src, &mut files)?;
    let readme = std::fs::read_to_string(root.join("README.md")).unwrap_or_default();
    let mut scans = Vec::with_capacity(files.len());
    for p in &files {
        let text =
            std::fs::read_to_string(p).map_err(|e| format!("read {}: {e}", p.display()))?;
        // report under repo-relative forward-slash paths
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        scans.push(scan::scan_source(&rel, &text));
    }
    let mut findings = Vec::new();
    for s in &scans {
        findings.extend(rules::check_file(s));
    }
    findings.extend(rules::check_protocol(&scans, &readme));
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(LintReport {
        findings,
        files_scanned: scans.len(),
    })
}

/// CLI options for `paretobandit lint`.
pub struct LintOpts {
    pub root: String,
    pub json: bool,
    pub deny: bool,
    pub baseline: Option<String>,
    pub write_baseline: bool,
}

/// Drive a lint run for the CLI; returns the process exit code.
/// Output goes to stdout; errors to stderr.
pub fn lint_main(opts: &LintOpts) -> i32 {
    let root = Path::new(&opts.root);
    let baseline_path = opts
        .baseline
        .clone()
        .unwrap_or_else(|| root.join(BASELINE_FILE).to_string_lossy().into_owned());
    let report = match run_lint(root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            return 2;
        }
    };
    let baseline: BTreeMap<String, usize> = match load_baseline(&baseline_path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            return 2;
        }
    };
    if opts.write_baseline {
        if let Err(e) = write_baseline(&baseline_path, &report.counts()) {
            eprintln!("pallas-lint: {e}");
            return 2;
        }
        println!(
            "pallas-lint: wrote {} bucket(s) to {}",
            report.counts().len(),
            baseline_path
        );
        return 0;
    }
    if opts.json {
        println!("{}", report.to_json().to_string());
    } else {
        print!("{}", report.render_text(&baseline));
    }
    if opts.deny && !report.violations(&baseline).is_empty() {
        if opts.json {
            // the human summary already printed the buckets in text mode
            for v in report.violations(&baseline) {
                eprintln!(
                    "pallas-lint: baseline EXCEEDED: {} has {} finding(s), allowance {}",
                    v.key, v.current, v.baseline
                );
            }
        }
        return 1;
    }
    0
}
