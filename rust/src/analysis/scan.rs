//! Source scanner for the in-repo lint pass (`pallas-lint`).
//!
//! A hand-rolled Rust tokenizer good enough for line-level rules: it
//! strips comments, blanks string/char-literal contents (so rule patterns
//! never fire inside literals or doc examples), tracks `#[cfg(test)]`
//! regions (test code is exempt from serving-path rules), and records
//! function spans together with their `// lint:` markers.
//!
//! The scanner is deliberately NOT a parser — no `syn`, no rustc plumbing
//! (the build image has no registry access) — so rules key off blanked
//! token text plus brace/paren counting.  That trade-off is documented in
//! `docs/analysis.md`; the conservative failure mode is a false positive,
//! which the `// lint: allow(<rule>) reason="..."` grammar handles.

/// One source line, three views.
pub struct ScanLine {
    /// the untouched source text (cross-file rules read literals here)
    pub raw: String,
    /// comments removed, string/char contents blanked with spaces
    pub code: String,
    /// comment text carried by this line (line + block comments)
    pub comment: String,
    /// inside a `#[cfg(test)]` item
    pub in_test: bool,
}

/// A function body span (0-based line indices, inclusive).
pub struct FnSpan {
    pub name: String,
    /// line holding the `fn` keyword
    pub sig_line: usize,
    /// first line of the body (the opening brace)
    pub start: usize,
    /// line of the matching closing brace
    pub end: usize,
    /// `// lint: no_alloc` marker in the doc/attribute block above
    pub no_alloc: bool,
    /// function-level `// lint: allow(<rule>) reason="..."` markers
    pub allows: Vec<String>,
}

impl FnSpan {
    pub fn contains(&self, line: usize) -> bool {
        line >= self.sig_line && line <= self.end
    }
}

/// A scanned file: lines plus the recognized function spans.
pub struct FileScan {
    /// repo-relative path with forward slashes (e.g. `rust/src/server/api.rs`)
    pub path: String,
    pub lines: Vec<ScanLine>,
    pub fns: Vec<FnSpan>,
}

impl FileScan {
    /// Is the finding at `line` (0-based) suppressed for `rule`?  A
    /// suppression is a well-formed `lint: allow(<rule>) reason="..."`
    /// comment on the same line, on the line directly above, or in the
    /// marker block of the enclosing function.
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        let hit = |i: usize| allow_rules(&self.lines[i].comment, true).iter().any(|r| r == rule);
        if hit(line) || (line > 0 && hit(line - 1)) {
            return true;
        }
        self.fns
            .iter()
            .any(|f| f.contains(line) && f.allows.iter().any(|r| r == rule))
    }

    /// Does the atomic site at `line` carry an invariant comment?  The
    /// comment must contain `invariant:` on the same line or within the
    /// five lines above (a multi-line comment or a cluster of adjacent
    /// sites may share one).
    pub fn has_invariant(&self, line: usize) -> bool {
        let lo = line.saturating_sub(5);
        (lo..=line).any(|i| self.lines[i].comment.contains("invariant:"))
    }

    /// The innermost no-alloc-marked span containing `line`, if any.
    pub fn no_alloc_span(&self, line: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.no_alloc && f.contains(line))
            .min_by_key(|f| f.end - f.sig_line)
    }
}

/// Extract the rule names of allow markers in a comment.  With
/// `require_reason`, markers missing `reason="..."` are dropped (the
/// suppression-hygiene rule reports them separately).
pub fn allow_rules(comment: &str, require_reason: bool) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(i) = rest.find("lint: allow(") {
        let tail = &rest[i + "lint: allow(".len()..];
        if let Some(j) = tail.find(')') {
            let rule = tail[..j].trim().to_string();
            if !rule.is_empty() && (!require_reason || tail[j..].contains("reason=\"")) {
                out.push(rule);
            }
            rest = &tail[j..];
        } else {
            break;
        }
    }
    out
}

/// Count allow markers (well-formed or not) in a comment — the
/// suppression-hygiene rule uses this to flag reason-less allows.
pub fn allow_markers(comment: &str) -> usize {
    comment.matches("lint: allow(").count()
}

// ----------------------------------------------------------------------
// pass 1: comment/string separation

enum St {
    Normal,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    Char,
}

/// Scan source text into a [`FileScan`].  `path` is the repo-relative
/// label findings are reported under (virtual paths are fine in tests).
pub fn scan_source(path: &str, src: &str) -> FileScan {
    let bytes: Vec<char> = src.chars().collect();
    let mut code = String::with_capacity(src.len());
    let mut comment = String::with_capacity(64);
    let mut st = St::Normal;
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i];
        let next = bytes.get(i + 1).copied().unwrap_or('\0');
        match st {
            St::Normal => {
                if c == '/' && next == '/' {
                    st = St::LineComment;
                    code.push(' ');
                    code.push(' ');
                    comment.push('/');
                    comment.push('/');
                    i += 2;
                    continue;
                }
                if c == '/' && next == '*' {
                    st = St::BlockComment(1);
                    code.push(' ');
                    code.push(' ');
                    comment.push('/');
                    comment.push('*');
                    i += 2;
                    continue;
                }
                if c == '"' {
                    st = St::Str;
                    code.push('"');
                    comment.push(' ');
                    i += 1;
                    continue;
                }
                // raw strings: r"..." / r#"..."# / br#"..."#
                if (c == 'r' || (c == 'b' && next == 'r')) && !prev_is_ident(&code) {
                    let mut j = i + if c == 'b' { 2 } else { 1 };
                    let mut hashes = 0u32;
                    while bytes.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if bytes.get(j) == Some(&'"') {
                        for _ in i..=j {
                            code.push(' ');
                            comment.push(' ');
                        }
                        st = St::RawStr(hashes);
                        i = j + 1;
                        continue;
                    }
                }
                if c == '\'' {
                    // char literal vs lifetime: a literal closes within a
                    // few chars ('x', '\n', '\u{..}'); a lifetime doesn't
                    if next == '\\' || matches!(bytes.get(i + 2), Some('\'')) {
                        st = St::Char;
                        code.push('\'');
                        comment.push(' ');
                        i += 1;
                        continue;
                    }
                }
                code.push(c);
                comment.push(if c == '\n' { '\n' } else { ' ' });
                i += 1;
            }
            St::LineComment => {
                if c == '\n' {
                    st = St::Normal;
                    code.push('\n');
                    comment.push('\n');
                } else {
                    code.push(' ');
                    comment.push(c);
                }
                i += 1;
            }
            St::BlockComment(depth) => {
                if c == '*' && next == '/' {
                    let d = depth - 1;
                    st = if d == 0 { St::Normal } else { St::BlockComment(d) };
                    code.push(' ');
                    code.push(' ');
                    comment.push('*');
                    comment.push('/');
                    i += 2;
                } else if c == '/' && next == '*' {
                    st = St::BlockComment(depth + 1);
                    code.push(' ');
                    code.push(' ');
                    comment.push('/');
                    comment.push('*');
                    i += 2;
                } else {
                    code.push(if c == '\n' { '\n' } else { ' ' });
                    comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    code.push(' ');
                    code.push(' ');
                    comment.push(' ');
                    comment.push(' ');
                    i += 2;
                } else if c == '"' {
                    st = St::Normal;
                    code.push('"');
                    comment.push(' ');
                    i += 1;
                } else {
                    code.push(if c == '\n' { '\n' } else { ' ' });
                    comment.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for h in 0..hashes {
                        if bytes.get(i + 1 + h as usize) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        for _ in 0..=(hashes as usize) {
                            code.push(' ');
                            comment.push(' ');
                        }
                        st = St::Normal;
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
                code.push(if c == '\n' { '\n' } else { ' ' });
                comment.push(if c == '\n' { '\n' } else { ' ' });
                i += 1;
            }
            St::Char => {
                if c == '\\' {
                    code.push(' ');
                    code.push(' ');
                    comment.push(' ');
                    comment.push(' ');
                    i += 2;
                } else if c == '\'' {
                    st = St::Normal;
                    code.push('\'');
                    comment.push(' ');
                    i += 1;
                } else {
                    code.push(' ');
                    comment.push(' ');
                    i += 1;
                }
            }
        }
    }

    let raws: Vec<&str> = src.split('\n').collect();
    let codes: Vec<&str> = code.split('\n').collect();
    let comments: Vec<&str> = comment.split('\n').collect();
    let n = raws.len();
    let mut lines: Vec<ScanLine> = (0..n)
        .map(|k| ScanLine {
            raw: raws[k].to_string(),
            code: codes.get(k).copied().unwrap_or("").to_string(),
            comment: comments.get(k).copied().unwrap_or("").to_string(),
            in_test: false,
        })
        .collect();

    mark_test_regions(&mut lines);
    let fns = find_fns(&lines);
    FileScan {
        path: path.to_string(),
        lines,
        fns,
    }
}

fn prev_is_ident(code: &str) -> bool {
    code.chars()
        .last()
        .map(|c| c.is_ascii_alphanumeric() || c == '_')
        .unwrap_or(false)
}

// ----------------------------------------------------------------------
// pass 2: #[cfg(test)] regions

fn mark_test_regions(lines: &mut [ScanLine]) {
    let mut k = 0;
    while k < lines.len() {
        if lines[k].code.contains("#[cfg(test)]") && !lines[k].in_test {
            // skip the attributed item: everything until its braces
            // balance (or, for brace-less items like `use`, to the `;`)
            let mut depth = 0i32;
            let mut seen_brace = false;
            let mut j = k;
            while j < lines.len() {
                lines[j].in_test = true;
                for c in lines[j].code.chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            seen_brace = true;
                        }
                        '}' => depth -= 1,
                        ';' if !seen_brace => {
                            depth = -1; // statement item: done
                        }
                        _ => {}
                    }
                    if seen_brace && depth == 0 {
                        break;
                    }
                    if depth < 0 {
                        break;
                    }
                }
                if (seen_brace && depth == 0) || depth < 0 {
                    break;
                }
                j += 1;
            }
            k = j + 1;
        } else {
            k += 1;
        }
    }
}

// ----------------------------------------------------------------------
// pass 3: function spans + markers

fn find_fns(lines: &[ScanLine]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    for (k, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let Some(name) = fn_name_on(&line.code) else {
            continue;
        };
        // find the body's opening brace at paren depth 0; a `;` first
        // means a trait/extern declaration without a body
        let mut paren = 0i32;
        let mut open: Option<(usize, usize)> = None; // (line, col)
        'outer: for (j, l) in lines.iter().enumerate().skip(k) {
            let cs: Vec<char> = l.code.chars().collect();
            let from = if j == k {
                l.code.find("fn ").map(|b| l.code[..b].chars().count()).unwrap_or(0)
            } else {
                0
            };
            for (col, &c) in cs.iter().enumerate().skip(from) {
                match c {
                    '(' => paren += 1,
                    ')' => paren -= 1,
                    ';' if paren == 0 => break 'outer,
                    '{' if paren == 0 => {
                        open = Some((j, col));
                        break 'outer;
                    }
                    _ => {}
                }
            }
        }
        let Some((start, col)) = open else { continue };
        // brace-count to the end of the body
        let mut depth = 0i32;
        let mut end = start;
        'count: for (j, l) in lines.iter().enumerate().skip(start) {
            for (c2, c) in l.code.chars().enumerate() {
                if j == start && c2 < col {
                    continue;
                }
                match c {
                    '{' => depth += 1,
                    '}' => {
                        depth -= 1;
                        if depth == 0 {
                            end = j;
                            break 'count;
                        }
                    }
                    _ => {}
                }
            }
        }
        // marker block: contiguous comment/attribute/empty lines above
        let mut no_alloc = false;
        let mut allows = Vec::new();
        let mut j = k;
        while j > 0 {
            j -= 1;
            let l = &lines[j];
            let code_t = l.code.trim();
            let is_meta = code_t.is_empty() || code_t.starts_with('#') || code_t.ends_with(']');
            if !is_meta && l.comment.trim().is_empty() {
                break;
            }
            if !is_meta {
                break;
            }
            if l.comment.contains("lint: no_alloc") {
                no_alloc = true;
            }
            allows.extend(allow_rules(&l.comment, true));
        }
        // a marker on the `fn` line itself also counts
        if lines[k].comment.contains("lint: no_alloc") {
            no_alloc = true;
        }
        allows.extend(allow_rules(&lines[k].comment, true));
        out.push(FnSpan {
            name,
            sig_line: k,
            start,
            end,
            no_alloc,
            allows,
        });
    }
    out
}

/// The function name if this code line declares one (`fn name(`),
/// ignoring `fn` inside identifiers and type positions like `Fn(`.
fn fn_name_on(code: &str) -> Option<String> {
    let mut from = 0;
    while let Some(b) = code[from..].find("fn ") {
        let at = from + b;
        let before_ok = at == 0
            || code[..at]
                .chars()
                .last()
                .map(|c| !(c.is_ascii_alphanumeric() || c == '_'))
                .unwrap_or(true);
        if before_ok {
            let rest = code[at + 3..].trim_start();
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        from = at + 3;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let s = scan_source(
            "x.rs",
            "let a = \"panic!(x.unwrap())\"; // trailing .unwrap()\nlet b = 1;\n",
        );
        assert!(!s.lines[0].code.contains("panic!"));
        assert!(!s.lines[0].code.contains(".unwrap()"));
        assert!(s.lines[0].comment.contains(".unwrap()"));
        assert!(s.lines[1].code.contains("let b"));
    }

    #[test]
    fn raw_strings_and_char_literals() {
        let s = scan_source(
            "x.rs",
            "let r = r#\"panic!()\"#;\nlet c = '\\n';\nlet lt: &'static str = \"\";\n",
        );
        assert!(!s.lines[0].code.contains("panic!"));
        assert!(s.lines[2].code.contains("static"), "lifetime untouched");
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn helper() { x.unwrap(); }\n}\nfn live2() {}\n";
        let s = scan_source("x.rs", src);
        assert!(!s.lines[0].in_test);
        assert!(s.lines[3].in_test);
        assert!(!s.lines[5].in_test);
    }

    #[test]
    fn fn_spans_and_markers() {
        let src = "\
/// docs
// lint: no_alloc
// lint: allow(index) reason=\"bounded by caller\"
pub fn hot(&mut self, x: &[f64]) -> f64 {
    let y = x[0];
    y
}

pub fn cold() {
}
";
        let s = scan_source("x.rs", src);
        assert_eq!(s.fns.len(), 2);
        let hot = &s.fns[0];
        assert_eq!(hot.name, "hot");
        assert!(hot.no_alloc);
        assert_eq!(hot.allows, vec!["index".to_string()]);
        assert!(hot.contains(4));
        assert!(!hot.contains(8));
        assert!(s.allowed("index", 4));
        assert!(!s.allowed("panic", 4));
        assert!(!s.fns[1].no_alloc);
    }

    #[test]
    fn allow_requires_a_reason() {
        let s = scan_source(
            "x.rs",
            "x.unwrap(); // lint: allow(panic)\ny.unwrap(); // lint: allow(panic) reason=\"checked above\"\n",
        );
        assert!(!s.allowed("panic", 0), "reason-less allow must not suppress");
        assert!(s.allowed("panic", 1));
        assert_eq!(allow_markers(&s.lines[0].comment), 1);
    }

    #[test]
    fn invariant_comment_window() {
        let src = "// invariant: monotonic counter\nlet a = 1;\nlet b = 2;\nlet c = 3;\nlet d = 4;\nlet e = 5;\nlet f = 6;\n";
        let s = scan_source("x.rs", src);
        assert!(s.has_invariant(1));
        assert!(s.has_invariant(5));
        assert!(!s.has_invariant(6), "window is five lines");
    }
}
