//! Declarative scenario engine: drift schedules as data, not code.
//!
//! The paper's claim is adaptation under shift — price cuts, silent
//! quality regressions, runtime onboarding — but each shift used to be a
//! hardcoded `exp/exp*.rs` binary.  This module turns a non-stationary
//! serving scenario into a ~20-line TOML/JSON spec:
//!
//! * [`spec`] — the schema: a `[scenario]` header plus a schedule of
//!   timed `[[event]]`s (`set_price`, `degrade_quality`, `add_model`,
//!   `remove_model`, `set_budget`, `traffic_mix`, `snapshot`,
//!   `restart`, and the streaming-inventory verbs `offer_model` /
//!   `expire_model` / `set_slots` / `stream_inventory`), parsed by the
//!   in-tree TOML-subset reader ([`toml`]).
//! * [`run`] — execution: in-process against any hosted policy
//!   ([`crate::router::PolicyHost`], [`run_scenario`]), or over the v2
//!   wire protocol against a live `serve --workers N` engine
//!   ([`run_scenario_wire`]) using the `inject` / `snapshot` / `restore`
//!   admin verbs.
//! * [`snapshot`] — the versioned on-disk router snapshot behind the
//!   `snapshot`/`restart` events, the wire verbs and `serve --restore`.
//!
//! The shipped specs under `scenarios/` port the paper's exp2 (cost
//! drift), exp3 (degradation) and exp4 (onboarding); the experiment
//! modules load them instead of hardcoding their timelines, so the specs
//! are continuously regression-checked against the paper's headline
//! numbers.  See `docs/scenarios.md` for the schema reference and
//! `docs/operations.md` for the snapshot/warm-restart runbook.

pub mod run;
pub mod snapshot;
pub mod spec;
pub mod toml;

pub use run::{run_scenario, run_scenario_wire, RunOptions, ScenarioRun};
pub use spec::{Event, ScenarioSpec, Stream, TimedEvent};
pub use toml::parse_toml;
