//! Minimal TOML-subset parser for scenario specs.
//!
//! The offline build image has no `toml` crate, so scenario files are
//! parsed by this small hand-rolled reader into the crate's [`Json`]
//! value model (the same value model the JSON spec path produces, so
//! [`super::ScenarioSpec`] decodes both formats identically).
//!
//! Supported subset — everything `scenarios/*.toml` needs and nothing
//! more:
//!
//! * `# comments` (full-line and trailing, outside strings)
//! * `[table]` and `[[array-of-tables]]` headers (single-level names)
//! * `key = value` with basic strings (`"..."` with `\"`, `\\`, `\n`,
//!   `\t` escapes), integers, floats (including scientific notation) and
//!   booleans
//!
//! Dotted keys, inline tables, arrays, multi-line strings and datetimes
//! are rejected with a line-numbered error rather than silently
//! misread.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Parse a TOML-subset document into a [`Json::Obj`].  `[[name]]` tables
/// accumulate into a `Json::Arr` under `name`, preserving file order.
pub fn parse_toml(src: &str) -> Result<Json, String> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    // (table name, is array-of-tables) the next key lines write into
    let mut cur: Option<(String, bool)> = None;
    for (n, raw) in src.lines().enumerate() {
        let ln = n + 1;
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix("[[").and_then(|r| r.strip_suffix("]]")) {
            let name = check_key(name.trim(), ln)?;
            let entry = root
                .entry(name.clone())
                .or_insert_with(|| Json::Arr(Vec::new()));
            match entry {
                Json::Arr(v) => v.push(Json::Obj(BTreeMap::new())),
                _ => return Err(format!("line {ln}: [[{name}]] clashes with a non-array key")),
            }
            cur = Some((name, true));
        } else if let Some(name) = line.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
            let name = check_key(name.trim(), ln)?;
            if root.contains_key(&name) {
                return Err(format!("line {ln}: duplicate table [{name}]"));
            }
            root.insert(name.clone(), Json::Obj(BTreeMap::new()));
            cur = Some((name, false));
        } else {
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {ln}: expected `key = value` or a [table] header"));
            };
            let key = check_key(k.trim(), ln)?;
            let val = parse_value(v.trim(), ln)?;
            let target = match &cur {
                None => &mut root,
                Some((name, false)) => match root.get_mut(name) {
                    Some(Json::Obj(m)) => m,
                    _ => return Err(format!("line {ln}: lost table [{name}]")),
                },
                Some((name, true)) => match root.get_mut(name) {
                    Some(Json::Arr(arr)) => match arr.last_mut() {
                        Some(Json::Obj(m)) => m,
                        _ => return Err(format!("line {ln}: lost table [[{name}]]")),
                    },
                    _ => return Err(format!("line {ln}: lost table [[{name}]]")),
                },
            };
            if target.insert(key.clone(), val).is_some() {
                return Err(format!("line {ln}: duplicate key '{key}'"));
            }
        }
    }
    Ok(Json::Obj(root))
}

/// Drop a trailing `# comment`, ignoring `#` inside basic strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str && !escaped => {
                escaped = true;
                continue;
            }
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        escaped = false;
    }
    line
}

/// Bare keys only: ASCII letters, digits, `_`, `-`.
fn check_key(k: &str, ln: usize) -> Result<String, String> {
    if !k.is_empty()
        && k.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
    {
        Ok(k.to_string())
    } else {
        Err(format!("line {ln}: invalid key '{k}'"))
    }
}

fn parse_value(v: &str, ln: usize) -> Result<Json, String> {
    if let Some(rest) = v.strip_prefix('"') {
        return parse_string(rest, ln);
    }
    match v {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    // TOML allows `1_000` separators; strip them before the float parse
    let cleaned: String = v.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("line {ln}: unsupported value '{v}' (string/number/bool only)"))
}

fn parse_string(rest: &str, ln: usize) -> Result<Json, String> {
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                let tail: String = chars.collect();
                if tail.trim().is_empty() {
                    return Ok(Json::Str(out));
                }
                return Err(format!("line {ln}: trailing data after string"));
            }
            '\\' => match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                _ => return Err(format!("line {ln}: unsupported string escape")),
            },
            c => out.push(c),
        }
    }
    Err(format!("line {ln}: unterminated string"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_arrays_and_scalars() {
        let doc = r##"
# scenario header
[scenario]
name = "exp2_costdrift"   # trailing comment
steps = 1824
budget = 6.6e-4
paced = true

[[event]]
at = 608
op = "set_price"
mult = 0.017777777777777778

[[event]]
at = 1216
op = "traffic_mix"
stream = "replay"
phase = 0
"##;
        let j = parse_toml(doc).unwrap();
        let sc = j.get("scenario").unwrap();
        assert_eq!(sc.get("name").unwrap().as_str(), Some("exp2_costdrift"));
        assert_eq!(sc.get("steps").unwrap().as_f64(), Some(1824.0));
        assert_eq!(sc.get("budget").unwrap().as_f64(), Some(6.6e-4));
        assert_eq!(sc.get("paced").unwrap().as_bool(), Some(true));
        let evs = j.get("event").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("op").unwrap().as_str(), Some("set_price"));
        assert_eq!(
            evs[0].get("mult").unwrap().as_f64(),
            Some(0.017777777777777778)
        );
        assert_eq!(evs[1].get("phase").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn root_keys_before_any_table() {
        let j = parse_toml("version = 1\nname = \"x\"\n").unwrap();
        assert_eq!(j.get("version").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("name").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn string_escapes_and_hash_inside_string() {
        let j = parse_toml("[t]\nk = \"a # not a comment \\\"q\\\" \\n\"\n").unwrap();
        assert_eq!(
            j.get("t").unwrap().get("k").unwrap().as_str(),
            Some("a # not a comment \"q\" \n")
        );
    }

    #[test]
    fn underscore_separators_parse() {
        let j = parse_toml("[t]\nn = 1_824\n").unwrap();
        assert_eq!(j.get("t").unwrap().get("n").unwrap().as_f64(), Some(1824.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        for (doc, frag) in [
            ("[t]\nk v\n", "line 2"),
            ("[t]\nk = [1, 2]\n", "unsupported value"),
            ("[t]\nk = \"unterminated\n", "unterminated"),
            ("[t]\n[t]\n", "duplicate table"),
            ("[t]\nk = 1\nk = 2\n", "duplicate key"),
            ("[bad key]\nk = 1\n", "invalid key"),
            ("[[t]]\nk = 1\n[t]\n", "duplicate table"),
        ] {
            let e = parse_toml(doc).unwrap_err();
            assert!(e.contains(frag), "{doc:?} -> {e}");
        }
    }

    #[test]
    fn array_table_after_scalar_key_rejected() {
        let e = parse_toml("event = 1\n[[event]]\nk = 2\n").unwrap_err();
        assert!(e.contains("clashes"), "{e}");
    }
}
