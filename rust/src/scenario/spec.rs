//! Declarative scenario specs: a portfolio + a schedule of timed drift
//! events, loadable from TOML (`scenarios/*.toml`) or JSON.
//!
//! A spec is the serialized form of one non-stationary serving scenario —
//! what used to be a hardcoded `exp/exp*.rs` phase script becomes a
//! ~20-line file:
//!
//! ```
//! use paretobandit::scenario::{Event, ScenarioSpec};
//! let spec = ScenarioSpec::from_toml(r#"
//!     [scenario]
//!     name = "price-cut"
//!     steps = 100
//!     k = 3
//!
//!     [[event]]
//!     at = 50
//!     op = "set_price"
//!     model = "gemini-2.5-pro"
//!     mult = 0.0178
//! "#).unwrap();
//! assert_eq!(spec.events.len(), 1);
//! assert_eq!(spec.events[0].at, 50);
//! assert!(matches!(spec.events[0].event, Event::SetPrice { .. }));
//! ```
//!
//! Event verbs (the `op` field): `set_price`, `degrade_quality`,
//! `add_model`, `remove_model`, `set_budget`, `traffic_mix`, `snapshot`,
//! `restart`, and — for specs that name a `deploy` policy — the
//! streaming-inventory verbs `offer_model`, `expire_model`, `set_slots`
//! and the plan-time generator `stream_inventory`.  See
//! `docs/scenarios.md` for the full schema reference and the annotated
//! exp2/exp3/exp4 ports.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::toml::parse_toml;

/// Which prompt stream a `traffic_mix` event switches to.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Stream {
    /// continue consuming the seeded shuffle of the evaluation split
    Fresh,
    /// replay an earlier segment's prompts, reshuffled with the spec's
    /// replay salt (the papers' within-subject phase-3 design)
    Replay(usize),
}

/// One scheduled drift/operations event.
///
/// `set_price` / `degrade_quality` / `traffic_mix` describe the
/// *environment*; `add_model` / `remove_model` / `set_budget` /
/// `snapshot` / `restart` act on the router (in-process) or on a live
/// engine (over the wire via the `inject` / `snapshot` / `restore`
/// verbs).
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Drift a model's market price.  `mult` scales the environment's
    /// realised costs (and, when prices are omitted, the list prices the
    /// router is repriced with); explicit `price_in`/`price_out` are what
    /// a wire host injects since the engine cannot see the simulator.
    SetPrice {
        model: String,
        mult: Option<f64>,
        price_in: Option<f64>,
        price_out: Option<f64>,
    },
    /// Silently shift a model's mean reward to `mean_to` (cost
    /// unchanged); `None` restores the baseline quality.
    DegradeQuality { model: String, mean_to: Option<f64> },
    /// Register a model at runtime (hot-swap onboarding).  Prices default
    /// to the world bank's list prices; `n_eff`+`r0` select a heuristic
    /// prior, otherwise the model starts cold.
    AddModel {
        model: String,
        price_in: Option<f64>,
        price_out: Option<f64>,
        n_eff: Option<f64>,
        r0: Option<f64>,
    },
    /// Retire a model (its slot id is tombstoned, never reused; the name
    /// becomes free for a later `add_model`).
    RemoveModel { model: String },
    /// Change the $/request ceiling at runtime (λ state is preserved).
    SetBudget { budget: f64 },
    /// Switch the prompt stream (phase boundary; see [`Stream`]).
    TrafficMix { stream: Stream },
    /// Persist the router state; in-process runs also keep it in memory
    /// for a later pathless `restart`.
    Snapshot { path: Option<String> },
    /// Warm-restart the router from `path` (or the last in-memory
    /// snapshot when omitted).
    Restart { path: Option<String> },
    /// Offer a candidate model to the deployment layer (streaming
    /// inventory; needs a `deploy` policy in the spec or on the server).
    /// Prices default to the world bank's list prices for the candidate's
    /// base model; a wire host always injects them explicitly.
    OfferModel {
        model: String,
        price_in: Option<f64>,
        price_out: Option<f64>,
        /// prior quality hint in [0,1]
        quality: Option<f64>,
    },
    /// Withdraw a candidate: dropped from the pool, or evicted from its
    /// slot if deployed.  Unknown names are a no-op.
    ExpireModel { model: String },
    /// Resize the deployment slot cap at runtime.
    SetSlots { k: usize },
    /// Generator verb: expands at plan time into `count` seeded
    /// `offer_model` events (and matching `expire_model` events when
    /// `expire_after` is set) spaced `every` steps starting at this
    /// event's `at`.  Never travels the wire.
    StreamInventory {
        count: u64,
        every: u64,
        expire_after: Option<u64>,
        seed: u64,
    },
}

impl Event {
    /// The wire/spec verb name for this event.
    pub fn op(&self) -> &'static str {
        match self {
            Event::SetPrice { .. } => "set_price",
            Event::DegradeQuality { .. } => "degrade_quality",
            Event::AddModel { .. } => "add_model",
            Event::RemoveModel { .. } => "remove_model",
            Event::SetBudget { .. } => "set_budget",
            Event::TrafficMix { .. } => "traffic_mix",
            Event::Snapshot { .. } => "snapshot",
            Event::Restart { .. } => "restart",
            Event::OfferModel { .. } => "offer_model",
            Event::ExpireModel { .. } => "expire_model",
            Event::SetSlots { .. } => "set_slots",
            Event::StreamInventory { .. } => "stream_inventory",
        }
    }

    /// True for events that only change the simulated environment — a
    /// serving engine has nothing to apply for them, so the `inject`
    /// wire verb rejects them as `bad_request`.
    pub fn is_env_side(&self) -> bool {
        matches!(self, Event::DegradeQuality { .. } | Event::TrafficMix { .. })
    }

    /// Decode one event object (`{"op": "...", ...fields}`) — the single
    /// schema home shared by spec files and the `inject` wire verb.
    pub fn from_json(j: &Json) -> Result<Event, String> {
        let Some(op) = j.get("op").and_then(Json::as_str) else {
            return Err("event: missing op".to_string());
        };
        let f = |k: &str| j.get(k).and_then(Json::as_f64);
        let s = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
        let model = |op: &str| s("model").ok_or_else(|| format!("{op}: missing model"));
        match op {
            "set_price" => {
                let (mult, price_in, price_out) = (f("mult"), f("price_in"), f("price_out"));
                if mult.is_none() && (price_in.is_none() || price_out.is_none()) {
                    return Err("set_price: need mult or price_in+price_out".to_string());
                }
                Ok(Event::SetPrice {
                    model: model(op)?,
                    mult,
                    price_in,
                    price_out,
                })
            }
            "degrade_quality" => Ok(Event::DegradeQuality {
                model: model(op)?,
                mean_to: f("mean_to"),
            }),
            "add_model" => {
                let (n_eff, r0) = (f("n_eff"), f("r0"));
                if n_eff.is_some() != r0.is_some() {
                    return Err("add_model: n_eff and r0 must be given together".to_string());
                }
                Ok(Event::AddModel {
                    model: model(op)?,
                    price_in: f("price_in"),
                    price_out: f("price_out"),
                    n_eff,
                    r0,
                })
            }
            "remove_model" => Ok(Event::RemoveModel { model: model(op)? }),
            "set_budget" => {
                let budget = f("budget").ok_or("set_budget: missing budget")?;
                if !budget.is_finite() || budget <= 0.0 {
                    return Err("set_budget: budget must be positive and finite".to_string());
                }
                Ok(Event::SetBudget { budget })
            }
            "traffic_mix" => {
                let stream = match s("stream").as_deref() {
                    Some("fresh") | None => Stream::Fresh,
                    Some("replay") => {
                        let ph = f("phase").ok_or("traffic_mix: replay needs phase")?;
                        if ph < 0.0 || ph.fract() != 0.0 {
                            return Err("traffic_mix: phase must be a non-negative integer"
                                .to_string());
                        }
                        Stream::Replay(ph as usize)
                    }
                    Some(other) => {
                        return Err(format!("traffic_mix: unknown stream '{other}'"))
                    }
                };
                Ok(Event::TrafficMix { stream })
            }
            "snapshot" => Ok(Event::Snapshot { path: s("path") }),
            "restart" => Ok(Event::Restart { path: s("path") }),
            "offer_model" => {
                let quality = f("quality");
                if let Some(q) = quality {
                    if !(0.0..=1.0).contains(&q) {
                        return Err("offer_model: quality must be in [0,1]".to_string());
                    }
                }
                let (price_in, price_out) = (f("price_in"), f("price_out"));
                if price_in.is_some() != price_out.is_some() {
                    return Err(
                        "offer_model: price_in and price_out must be given together".to_string()
                    );
                }
                Ok(Event::OfferModel {
                    model: model(op)?,
                    price_in,
                    price_out,
                    quality,
                })
            }
            "expire_model" => Ok(Event::ExpireModel { model: model(op)? }),
            "set_slots" => {
                let k = match f("k") {
                    Some(x) if x >= 1.0 && x.fract() == 0.0 => x as usize,
                    _ => return Err("set_slots: k must be a positive integer".to_string()),
                };
                Ok(Event::SetSlots { k })
            }
            "stream_inventory" => {
                let u = |k: &str, default: Option<u64>| -> Result<Option<u64>, String> {
                    match j.get(k) {
                        None => Ok(default),
                        Some(v) => match v.as_f64() {
                            Some(x) if x >= 0.0 && x.fract() == 0.0 => Ok(Some(x as u64)),
                            _ => Err(format!(
                                "stream_inventory: {k} must be a non-negative integer"
                            )),
                        },
                    }
                };
                let count = u("count", None)?
                    .ok_or("stream_inventory: missing count")?;
                if count == 0 {
                    return Err("stream_inventory: count must be >= 1".to_string());
                }
                let every = u("every", Some(8))?.unwrap_or(8).max(1);
                Ok(Event::StreamInventory {
                    count,
                    every,
                    expire_after: u("expire_after", None)?,
                    seed: u("seed", Some(0))?.unwrap_or(0),
                })
            }
            other => Err(format!("unknown event op '{other}'")),
        }
    }

    /// Encode as the wire/spec object shape [`Event::from_json`] reads.
    pub fn to_json(&self) -> Json {
        fn opt_f(fields: &mut Vec<(&'static str, Json)>, k: &'static str, v: Option<f64>) {
            if let Some(x) = v {
                fields.push((k, Json::Num(x)));
            }
        }
        let mut fields: Vec<(&'static str, Json)> =
            vec![("op", Json::Str(self.op().to_string()))];
        match self {
            Event::SetPrice {
                model,
                mult,
                price_in,
                price_out,
            } => {
                opt_f(&mut fields, "mult", *mult);
                opt_f(&mut fields, "price_in", *price_in);
                opt_f(&mut fields, "price_out", *price_out);
                fields.push(("model", Json::Str(model.clone())));
            }
            Event::DegradeQuality { model, mean_to } => {
                opt_f(&mut fields, "mean_to", *mean_to);
                fields.push(("model", Json::Str(model.clone())));
            }
            Event::AddModel {
                model,
                price_in,
                price_out,
                n_eff,
                r0,
            } => {
                opt_f(&mut fields, "price_in", *price_in);
                opt_f(&mut fields, "price_out", *price_out);
                opt_f(&mut fields, "n_eff", *n_eff);
                opt_f(&mut fields, "r0", *r0);
                fields.push(("model", Json::Str(model.clone())));
            }
            Event::RemoveModel { model } => fields.push(("model", Json::Str(model.clone()))),
            Event::SetBudget { budget } => fields.push(("budget", Json::Num(*budget))),
            Event::TrafficMix { stream } => match stream {
                Stream::Fresh => fields.push(("stream", Json::Str("fresh".into()))),
                Stream::Replay(p) => {
                    fields.push(("stream", Json::Str("replay".into())));
                    fields.push(("phase", Json::Num(*p as f64)));
                }
            },
            Event::Snapshot { path } | Event::Restart { path } => {
                if let Some(p) = path {
                    fields.push(("path", Json::Str(p.clone())));
                }
            }
            Event::OfferModel {
                model,
                price_in,
                price_out,
                quality,
            } => {
                opt_f(&mut fields, "price_in", *price_in);
                opt_f(&mut fields, "price_out", *price_out);
                opt_f(&mut fields, "quality", *quality);
                fields.push(("model", Json::Str(model.clone())));
            }
            Event::ExpireModel { model } => fields.push(("model", Json::Str(model.clone()))),
            Event::SetSlots { k } => fields.push(("k", Json::Num(*k as f64))),
            Event::StreamInventory {
                count,
                every,
                expire_after,
                seed,
            } => {
                fields.push(("count", Json::Num(*count as f64)));
                fields.push(("every", Json::Num(*every as f64)));
                opt_f(&mut fields, "expire_after", expire_after.map(|x| x as f64));
                fields.push(("seed", Json::Num(*seed as f64)));
            }
        }
        Json::obj(fields)
    }
}

impl std::fmt::Display for Event {
    /// Stable one-line rendering (the scenario event log's line format).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_json().to_string())
    }
}

/// An event scheduled at global request step `at` (events fire before
/// the routing decision of step `at`; step 0 is the first request).
#[derive(Clone, Debug, PartialEq)]
pub struct TimedEvent {
    pub at: u64,
    pub event: Event,
}

/// A parsed scenario: run parameters plus the event timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: String,
    /// total request steps; 0 = run the evaluation split to exhaustion
    pub steps: u64,
    /// initial portfolio: the first `k` models of the world bank
    pub k: usize,
    /// default $/request ceiling (harnesses may override per run)
    pub budget: Option<f64>,
    /// routing policy to drive (`name[:arg]`, a builder-registry key —
    /// see `docs/policies.md`); `None` = the harness default
    /// (ParetoBandit with warmup priors)
    pub policy: Option<String>,
    /// seed offset for the prompt stream shuffle (`stream_seed + run seed`)
    pub stream_seed: u64,
    /// seed offset for replayed-segment reshuffles
    pub replay_salt: u64,
    /// deployment policy spec (`fifo` / `greedy[:n]` / `ucb[:w]`, a
    /// `crate::deploy` builder key); `None` = no deployment layer, and
    /// the streaming-inventory verbs are rejected at run start
    pub deploy: Option<String>,
    /// deployment slot cap K (only meaningful with `deploy`)
    pub slots: usize,
    /// timeline, stably sorted by `at`
    pub events: Vec<TimedEvent>,
}

impl ScenarioSpec {
    /// Decode a spec from the shared value model (both the TOML and JSON
    /// loaders land here).
    pub fn from_json(j: &Json) -> Result<ScenarioSpec, String> {
        let sc = j
            .get("scenario")
            .ok_or("spec: missing [scenario] table")?;
        let name = sc
            .get("name")
            .and_then(Json::as_str)
            .ok_or("spec: [scenario] needs a name")?
            .to_string();
        let get_u = |key: &str, default: u64| -> Result<u64, String> {
            match sc.get(key) {
                None => Ok(default),
                Some(v) => match v.as_f64() {
                    Some(x) if x >= 0.0 && x.fract() == 0.0 => Ok(x as u64),
                    _ => Err(format!("spec: {key} must be a non-negative integer")),
                },
            }
        };
        let budget = match sc.get("budget") {
            None => None,
            Some(v) => match v.as_f64() {
                Some(b) if b.is_finite() && b > 0.0 => Some(b),
                _ => return Err("spec: budget must be positive and finite".to_string()),
            },
        };
        let policy = match sc.get("policy") {
            None => None,
            Some(v) => match v.as_str() {
                Some(p) if !p.is_empty() => Some(p.to_string()),
                _ => return Err("spec: policy must be a non-empty string".to_string()),
            },
        };
        let deploy = match sc.get("deploy") {
            None => None,
            Some(v) => match v.as_str() {
                Some(p) if !p.is_empty() => Some(p.to_string()),
                _ => return Err("spec: deploy must be a non-empty string".to_string()),
            },
        };
        let mut events = Vec::new();
        if let Some(arr) = j.get("event").and_then(Json::as_arr) {
            for (i, ev) in arr.iter().enumerate() {
                let at = match ev.get("at").and_then(Json::as_f64) {
                    Some(x) if x >= 0.0 && x.fract() == 0.0 => x as u64,
                    _ => return Err(format!("spec: event {i}: missing/invalid at")),
                };
                let event =
                    Event::from_json(ev).map_err(|e| format!("spec: event {i}: {e}"))?;
                events.push(TimedEvent { at, event });
            }
        }
        events.sort_by_key(|e| e.at); // stable: same-step events keep file order
        Ok(ScenarioSpec {
            name,
            description: sc
                .get("description")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            steps: get_u("steps", 0)?,
            k: get_u("k", 3)? as usize,
            budget,
            policy,
            stream_seed: get_u("stream_seed", 9000)?,
            replay_salt: get_u("replay_salt", 0)?,
            deploy,
            slots: get_u("slots", 3)? as usize,
            events,
        })
    }

    /// Parse a TOML-subset spec document.
    pub fn from_toml(src: &str) -> Result<ScenarioSpec, String> {
        Self::from_json(&parse_toml(src)?)
    }

    /// Load a spec file; `.json` parses as JSON, anything else as TOML.
    pub fn load(path: &Path) -> Result<ScenarioSpec, String> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let parsed = if path.extension().and_then(|e| e.to_str()) == Some("json") {
            Json::parse(&src)?
        } else {
            parse_toml(&src)?
        };
        Self::from_json(&parsed).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Load `<scenario dir>/<name>.toml` (see [`ScenarioSpec::dir`]).
    pub fn load_named(name: &str) -> Result<ScenarioSpec, String> {
        Self::load(&Self::dir().join(format!("{name}.toml")))
    }

    /// Where spec files live: `$PB_SCENARIO_DIR`, else `<repo>/scenarios`.
    pub fn dir() -> PathBuf {
        match std::env::var("PB_SCENARIO_DIR") {
            Ok(d) if !d.is_empty() => PathBuf::from(d),
            _ => Path::new(env!("CARGO_MANIFEST_DIR")).join("scenarios"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
[scenario]
name = "mini"
description = "two-phase price cut"
steps = 40
k = 3
budget = 6.6e-4
stream_seed = 9000
replay_salt = 4242

[[event]]
at = 20
op = "traffic_mix"
stream = "fresh"

[[event]]
at = 20
op = "set_price"
model = "gemini-2.5-pro"
mult = 0.5

[[event]]
at = 30
op = "traffic_mix"
stream = "replay"
phase = 0
"#;

    #[test]
    fn toml_spec_roundtrips_through_the_value_model() {
        let spec = ScenarioSpec::from_toml(DOC).unwrap();
        assert_eq!(spec.name, "mini");
        assert_eq!(spec.steps, 40);
        assert_eq!(spec.k, 3);
        assert_eq!(spec.budget, Some(6.6e-4));
        assert_eq!(spec.events.len(), 3);
        // same-step events keep file order (traffic_mix before set_price)
        assert_eq!(spec.events[0].at, 20);
        assert!(matches!(spec.events[0].event, Event::TrafficMix { .. }));
        assert!(matches!(
            spec.events[1].event,
            Event::SetPrice { ref model, mult: Some(m), .. }
                if model == "gemini-2.5-pro" && m == 0.5
        ));
        assert_eq!(
            spec.events[2].event,
            Event::TrafficMix {
                stream: Stream::Replay(0)
            }
        );
    }

    #[test]
    fn events_roundtrip_json() {
        let evs = vec![
            Event::SetPrice {
                model: "m".into(),
                mult: Some(0.5),
                price_in: None,
                price_out: None,
            },
            Event::DegradeQuality {
                model: "m".into(),
                mean_to: Some(0.75),
            },
            Event::DegradeQuality {
                model: "m".into(),
                mean_to: None,
            },
            Event::AddModel {
                model: "flash".into(),
                price_in: Some(0.3),
                price_out: Some(2.5),
                n_eff: Some(20.0),
                r0: Some(0.7),
            },
            Event::RemoveModel { model: "m".into() },
            Event::SetBudget { budget: 1e-3 },
            Event::TrafficMix {
                stream: Stream::Replay(2),
            },
            Event::Snapshot {
                path: Some("/tmp/s.json".into()),
            },
            Event::Restart { path: None },
            Event::OfferModel {
                model: "nova@s1".into(),
                price_in: Some(0.3),
                price_out: Some(1.2),
                quality: Some(0.7),
            },
            Event::OfferModel {
                model: "nova@s2".into(),
                price_in: None,
                price_out: None,
                quality: None,
            },
            Event::ExpireModel {
                model: "nova@s1".into(),
            },
            Event::SetSlots { k: 4 },
            Event::StreamInventory {
                count: 200,
                every: 8,
                expire_after: Some(400),
                seed: 7,
            },
            Event::StreamInventory {
                count: 5,
                every: 1,
                expire_after: None,
                seed: 0,
            },
        ];
        for ev in evs {
            let back = Event::from_json(&ev.to_json()).unwrap();
            assert_eq!(back, ev, "{ev}");
        }
    }

    #[test]
    fn malformed_events_are_rejected() {
        for bad in [
            r#"{"op":"set_price","model":"m"}"#,
            r#"{"op":"set_price","mult":0.5}"#,
            r#"{"op":"add_model","model":"m","n_eff":20}"#,
            r#"{"op":"set_budget","budget":-1}"#,
            r#"{"op":"traffic_mix","stream":"replay"}"#,
            r#"{"op":"traffic_mix","stream":"nope"}"#,
            r#"{"op":"warp_reality"}"#,
            r#"{"no_op":1}"#,
            r#"{"op":"offer_model","model":"m","quality":1.5}"#,
            r#"{"op":"offer_model","model":"m","price_in":0.5}"#,
            r#"{"op":"offer_model","price_in":0.5,"price_out":1.0}"#,
            r#"{"op":"set_slots"}"#,
            r#"{"op":"set_slots","k":0}"#,
            r#"{"op":"stream_inventory"}"#,
            r#"{"op":"stream_inventory","count":0}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Event::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn spec_validation_errors() {
        assert!(ScenarioSpec::from_toml("[other]\nname = \"x\"\n").is_err());
        assert!(ScenarioSpec::from_toml("[scenario]\nsteps = 10\n").is_err());
        let e = ScenarioSpec::from_toml(
            "[scenario]\nname = \"x\"\n\n[[event]]\nop = \"snapshot\"\n",
        )
        .unwrap_err();
        assert!(e.contains("at"), "{e}");
        let e = ScenarioSpec::from_toml("[scenario]\nname = \"x\"\nbudget = 0\n").unwrap_err();
        assert!(e.contains("budget"), "{e}");
    }

    #[test]
    fn policy_key_parses_and_validates() {
        let spec = ScenarioSpec::from_toml(
            "[scenario]\nname = \"p\"\nsteps = 10\npolicy = \"epsilon:0.2\"\n",
        )
        .unwrap();
        assert_eq!(spec.policy.as_deref(), Some("epsilon:0.2"));
        let spec = ScenarioSpec::from_toml("[scenario]\nname = \"p\"\n").unwrap();
        assert_eq!(spec.policy, None);
        let e = ScenarioSpec::from_toml("[scenario]\nname = \"p\"\npolicy = 3\n").unwrap_err();
        assert!(e.contains("policy"), "{e}");
    }

    #[test]
    fn deploy_key_and_slots_parse() {
        let spec = ScenarioSpec::from_toml(
            "[scenario]\nname = \"d\"\nsteps = 10\ndeploy = \"ucb:32\"\nslots = 2\n",
        )
        .unwrap();
        assert_eq!(spec.deploy.as_deref(), Some("ucb:32"));
        assert_eq!(spec.slots, 2);
        let spec = ScenarioSpec::from_toml("[scenario]\nname = \"d\"\n").unwrap();
        assert_eq!(spec.deploy, None);
        assert_eq!(spec.slots, 3, "slots defaults to 3");
        let e =
            ScenarioSpec::from_toml("[scenario]\nname = \"d\"\ndeploy = 7\n").unwrap_err();
        assert!(e.contains("deploy"), "{e}");
    }

    #[test]
    fn json_specs_load_too() {
        let j = r#"{"scenario": {"name": "j", "steps": 10},
                    "event": [{"at": 5, "op": "set_budget", "budget": 0.001}]}"#;
        let spec = ScenarioSpec::from_json(&Json::parse(j).unwrap()).unwrap();
        assert_eq!(spec.name, "j");
        assert_eq!(spec.events.len(), 1);
        assert_eq!(spec.events[0].event, Event::SetBudget { budget: 0.001 });
    }
}
