//! Versioned on-disk router snapshots.
//!
//! One JSON document per file: `{"format": "paretobandit-snapshot",
//! "version": 1, "policy": "<builder key>", "state": {...}}` wrapping a
//! policy's learned state (for ParetoBandit: the pre-v2
//! [`crate::router::RouterState`] shape, so snapshot files written
//! before Policy API v2 — which carry no `policy` tag — keep loading).
//! The loader refuses unknown formats and future versions instead of
//! misreading them, and the writer goes through a `.tmp` + rename so a
//! crash mid-write never leaves a half-snapshot where a restore (or
//! `serve --restore`) would find it.
//!
//! Producers: the `snapshot` wire verb (engine: post-merge global
//! posterior as adopted by shard 0), the in-process scenario executor's
//! `snapshot` event, `replay --export-priors` (posteriors fitted
//! counterfactually from a captured decision log — see
//! [`crate::log::export_priors`]), and [`save`] / [`save_value`]
//! directly.  Consumers: the `restore` wire verb, `serve --restore
//! <path>`, and the scenario `restart` event.

use std::path::Path;

use crate::router::RouterState;
use crate::util::json::Json;

/// Current snapshot format version.
pub const SNAPSHOT_VERSION: u64 = 1;
/// Format tag guarding against feeding arbitrary JSON to `restore`.
pub const SNAPSHOT_FORMAT: &str = "paretobandit-snapshot";

/// Encode an arbitrary policy state as the versioned snapshot document.
/// `policy` is the builder-registry key ([`crate::router::PolicyHost::kind`]);
/// `None` omits the tag (pre-v2 documents).
pub fn value_to_json(policy: Option<&str>, state: &Json) -> Json {
    let mut fields = vec![
        ("format", Json::Str(SNAPSHOT_FORMAT.to_string())),
        ("version", Json::Num(SNAPSHOT_VERSION as f64)),
    ];
    if let Some(p) = policy {
        fields.push(("policy", Json::Str(p.to_string())));
    }
    fields.push(("state", state.clone()));
    Json::obj(fields)
}

/// Decode a snapshot document into `(policy tag, state)`, enforcing
/// format and version.  Pre-v2 documents have no tag.
pub fn value_from_json(j: &Json) -> Result<(Option<String>, Json), String> {
    match j.get("format").and_then(Json::as_str) {
        Some(SNAPSHOT_FORMAT) => {}
        other => {
            return Err(format!(
                "not a router snapshot (format tag {:?})",
                other.unwrap_or("<missing>")
            ))
        }
    }
    match j.get("version").and_then(Json::as_f64) {
        Some(v) if v == SNAPSHOT_VERSION as f64 => {}
        Some(v) => return Err(format!("unsupported snapshot version {v}")),
        None => return Err("snapshot: missing version".to_string()),
    }
    let policy = j.get("policy").and_then(Json::as_str).map(str::to_string);
    Ok((policy, j.get("state").ok_or("snapshot: missing state")?.clone()))
}

/// Encode a ParetoBandit state as the versioned snapshot document.
pub fn to_json(state: &RouterState) -> Json {
    value_to_json(Some("paretobandit"), &state.to_json())
}

/// Decode a snapshot document as a ParetoBandit [`RouterState`].
pub fn from_json(j: &Json) -> Result<RouterState, String> {
    let (policy, state) = value_from_json(j)?;
    if let Some(p) = policy {
        if p != "paretobandit" {
            return Err(format!("snapshot holds policy '{p}', not paretobandit"));
        }
    }
    RouterState::from_json(&state)
}

/// Write an arbitrary policy snapshot file (atomic: tmp file + rename).
pub fn save_value(path: &Path, policy: Option<&str>, state: &Json) -> Result<(), String> {
    let doc = value_to_json(policy, state).to_string();
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, doc.as_bytes()).map_err(|e| format!("{}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("{}: {e}", path.display()))
}

/// Read a snapshot file back into `(policy tag, state)`.
pub fn load_value(path: &Path) -> Result<(Option<String>, Json), String> {
    let src =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let j = Json::parse(&src).map_err(|e| format!("{}: {e}", path.display()))?;
    value_from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
}

/// Write a ParetoBandit snapshot file (atomic: tmp file + rename).
pub fn save(path: &Path, state: &RouterState) -> Result<(), String> {
    save_value(path, Some("paretobandit"), &state.to_json())
}

/// Read a snapshot file back into a [`RouterState`].
pub fn load(path: &Path) -> Result<RouterState, String> {
    let src =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let j = Json::parse(&src).map_err(|e| format!("{}: {e}", path.display()))?;
    from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::{ArmSnap, PacerSnap, SlotSnap};

    fn state() -> RouterState {
        RouterState {
            d: 2,
            t: 9,
            slots: vec![
                None,
                Some(SlotSnap {
                    name: "m".into(),
                    price_in: 0.4,
                    price_out: 1.6,
                    burnin_left: 0,
                    arm: ArmSnap {
                        a: vec![2.0, 0.1, 0.1, 3.0],
                        b: vec![1.0, 0.5],
                        last_upd: 8,
                        last_play: 9,
                        n_obs: 7,
                    },
                }),
            ],
            pacer: Some(PacerSnap {
                budget: 1e-3,
                lambda: 0.2,
                cbar: 1.1e-3,
            }),
            rng: ([1, 2, 3, u64::MAX - 5], None),
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("pb_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.snap.json");
        let st = state();
        save(&path, &st).unwrap();
        assert_eq!(load(&path).unwrap(), st);
        // the tmp intermediate is gone after the rename
        assert!(!path.with_extension("tmp").exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version_and_format_are_enforced() {
        let st = state();
        let mut j = to_json(&st);
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::Num(99.0));
        }
        assert!(from_json(&j).unwrap_err().contains("version 99"));
        let j = Json::obj(vec![("format", Json::Str("other".into()))]);
        assert!(from_json(&j).unwrap_err().contains("not a router snapshot"));
        assert!(from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn policy_tag_roundtrips_and_guards_cross_policy_restores() {
        let st = Json::obj(vec![("t", Json::Num(7.0))]);
        let dir = std::env::temp_dir().join(format!("pb_snap3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("eps.snap.json");
        save_value(&path, Some("epsilon"), &st).unwrap();
        let (tag, back) = load_value(&path).unwrap();
        assert_eq!(tag.as_deref(), Some("epsilon"));
        assert_eq!(back.get("t").unwrap().as_f64(), Some(7.0));
        // a non-paretobandit document must not decode as a RouterState
        let e = load(&path).unwrap_err();
        assert!(e.contains("holds policy 'epsilon'"), "{e}");
        // pre-v2 documents (no tag) still decode
        let (tag, _) = value_from_json(&Json::obj(vec![
            ("format", Json::Str(SNAPSHOT_FORMAT.into())),
            ("version", Json::Num(1.0)),
            ("state", Json::obj(vec![])),
        ]))
        .unwrap();
        assert_eq!(tag, None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_missing_and_garbage_files() {
        let dir = std::env::temp_dir().join(format!("pb_snap2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(load(&dir.join("nope.json")).is_err());
        let garbage = dir.join("garbage.json");
        std::fs::write(&garbage, b"{not json").unwrap();
        assert!(load(&garbage).is_err());
        let _ = std::fs::remove_file(&garbage);
    }
}
