//! Scenario execution: drive a spec's event timeline against a router.
//!
//! Two hosts share one planner:
//!
//! * [`run_scenario`] — in-process: the router lives in this process and
//!   routes the simulated prompt stream directly (the experiment-harness
//!   path; exp2/exp3/exp4 are thin wrappers over this).
//! * [`run_scenario_wire`] — over the v2 wire protocol: prompts are
//!   routed through a live server/engine via
//!   [`crate::client::ParetoClient`], rewards and costs come from the
//!   local simulator, and engine-side events travel as `inject` /
//!   `snapshot` / `restore` verbs.  Environment-side events
//!   (`degrade_quality`, `traffic_mix`) apply only to the local
//!   simulator view — the engine never sees the simulator.
//!
//! Both produce a [`ScenarioRun`]: per-phase step logs (phases are the
//! segments between `traffic_mix` events) plus a line-per-event log.
//! Every source of randomness is seeded, so the same spec + seed yields
//! a bit-identical run.

use std::collections::HashMap;
use std::path::Path;

use crate::client::ParetoClient;
use crate::deploy::{build_deploy, DeployAction, SlotManager, DEPLOY_PRIOR_N_EFF};
use crate::exp::{stream_order, ExpEnv, StepLog};
use crate::router::PolicyHost;
use crate::sim::{EnvView, World};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::snapshot;
use super::spec::{Event, ScenarioSpec, Stream, TimedEvent};

/// Per-run knobs the spec deliberately does not pin down.
#[derive(Clone, Copy, Debug)]
pub struct RunOptions {
    /// run seed; offsets the stream shuffle and replay reshuffles
    pub seed: u64,
    /// whether `set_price` events also reprice the router (list prices
    /// are public, but only conditions with a reprice hook — the paper's
    /// ParetoBandit and Recalibrated — consume the feed)
    pub reprice_router: bool,
}

/// One executed scenario: phase-segmented step logs + the event log.
#[derive(Clone, Debug)]
pub struct ScenarioRun {
    /// step logs split at `traffic_mix` boundaries (≥ 1 phase)
    pub phases: Vec<Vec<StepLog>>,
    /// one line per applied event, in application order
    pub event_log: Vec<String>,
}

impl ScenarioRun {
    /// All phases flattened into one chronological log.
    pub fn flat(&self) -> Vec<StepLog> {
        self.phases.iter().flatten().copied().collect()
    }
}

/// Expand `traffic_mix` events into concrete per-phase prompt streams.
///
/// The evaluation split is shuffled once with `stream_seed + seed`;
/// `fresh` segments consume it sequentially, `replay` segments reshuffle
/// an earlier segment's prompts with `replay_salt + seed` (the papers'
/// within-subject design).  Phase 0 is an implicit `fresh` segment
/// starting at step 0.
fn plan_segments(spec: &ScenarioSpec, env: &ExpEnv, seed: u64) -> Result<Vec<Vec<u32>>, String> {
    let order = stream_order(&env.corpus.test, spec.stream_seed + seed);
    let total = if spec.steps == 0 {
        order.len() as u64
    } else {
        spec.steps
    };
    if total > order.len() as u64 {
        return Err(format!(
            "spec '{}': {total} steps but the evaluation split has {} prompts",
            spec.name,
            order.len()
        ));
    }
    let mut bounds: Vec<(u64, Stream)> = vec![(0, Stream::Fresh)];
    for te in &spec.events {
        if let Event::TrafficMix { stream } = &te.event {
            if te.at == 0 {
                // explicit phase-0 override replaces the implicit one
                bounds[0] = (0, stream.clone());
                continue;
            }
            if te.at >= total {
                return Err(format!(
                    "spec '{}': traffic_mix at {} is beyond the run ({total} steps)",
                    spec.name, te.at
                ));
            }
            // events are sorted by `at`, so only duplicates can violate
            if bounds.len() > 1 && te.at <= bounds[bounds.len() - 1].0 {
                return Err(format!(
                    "spec '{}': traffic_mix steps must be strictly increasing",
                    spec.name
                ));
            }
            bounds.push((te.at, stream.clone()));
        }
    }
    let mut segments: Vec<Vec<u32>> = Vec::with_capacity(bounds.len());
    let mut consumed = 0usize;
    let mut n_replays = 0u64;
    for (i, (start, stream)) in bounds.iter().enumerate() {
        let end = bounds.get(i + 1).map(|b| b.0).unwrap_or(total);
        let len = (end - start) as usize;
        let prompts = match stream {
            Stream::Fresh => {
                if consumed + len > order.len() {
                    return Err(format!(
                        "spec '{}': fresh segments exhaust the evaluation split",
                        spec.name
                    ));
                }
                let p = order[consumed..consumed + len].to_vec();
                consumed += len;
                p
            }
            Stream::Replay(src) => {
                let src_prompts = segments.get(*src).cloned().ok_or_else(|| {
                    format!("spec '{}': replay of unknown phase {src}", spec.name)
                })?;
                if src_prompts.len() < len {
                    return Err(format!(
                        "spec '{}': replayed phase {src} is shorter than the segment",
                        spec.name
                    ));
                }
                // each replay segment gets its own reshuffle: the first
                // uses `replay_salt + seed` verbatim (the paper
                // harnesses' seeding), later ones mix in their ordinal
                // so two replays of the same source are not correlated
                let mut p = src_prompts;
                Rng::new(spec.replay_salt + seed + n_replays * 0x9E37).shuffle(&mut p);
                n_replays += 1;
                p.truncate(len);
                p
            }
        };
        segments.push(prompts);
    }
    Ok(segments)
}

/// Resolve a model name against the world bank.
fn world_index(world: &World, name: &str) -> Result<usize, String> {
    world
        .models
        .iter()
        .position(|m| m.name == name)
        .ok_or_else(|| format!("model '{name}' is not in the world bank"))
}

/// Resolve a (possibly synthesized) model name to its world base model:
/// exact match first, else the `@`-suffix convention — streaming
/// candidates are named `<base>@sN` and inherit the base model's
/// quality/latency profile (their *prices* are their own).
fn world_base_index(world: &World, name: &str) -> Result<usize, String> {
    if let Ok(i) = world_index(world, name) {
        return Ok(i);
    }
    if let Some((base, _)) = name.split_once('@') {
        return world_index(world, base);
    }
    Err(format!("model '{name}' is not in the world bank"))
}

/// Resolve a routed decision to the world model that actually serves it.
///
/// Router slot ids and world indices coincide only until hot-swap churn:
/// a remove + re-add lands the same model on a fresh slot, so rewards
/// and costs must be simulated for the model *named* by the slot, never
/// for `world.models[slot]` (which after churn is a different model — or
/// out of bounds).
fn world_model_of(world: &World, name: &str) -> Result<usize, String> {
    world_base_index(world, name).map_err(|e| format!("routed to {e}"))
}

/// Judge the realised cost of a routed request: the world's simulated
/// cost, rescaled when the serving model is a streaming candidate whose
/// offered prices differ from its base model's list prices.
fn judged_cost(
    world: &World,
    p: &crate::sim::Prompt,
    wm: usize,
    view: &EnvView,
    name: &str,
    cand_blend: &HashMap<String, f64>,
) -> f64 {
    let cost = world.cost_view(p, wm, view);
    match cand_blend.get(name) {
        Some(b) => cost * (b / world.models[wm].blended_per_1k()),
        None => cost,
    }
}

/// Expand `stream_inventory` generator events into concrete seeded
/// `offer_model` / `expire_model` events.  Candidate names are
/// `<base>@s<ordinal>` (globally unique across generators); prices are
/// the base model's list prices scaled by a seeded multiplier in
/// [0.5, 2.0); quality hints are seeded uniforms in [0.35, 0.95).
/// Synthesized events landing at or beyond the run end are dropped, so
/// open-ended streams stay valid.
fn expand_events(
    spec: &ScenarioSpec,
    world: &World,
    total: u64,
) -> Result<Vec<TimedEvent>, String> {
    if !spec
        .events
        .iter()
        .any(|te| matches!(te.event, Event::StreamInventory { .. }))
    {
        return Ok(spec.events.clone());
    }
    if spec.deploy.is_none() {
        return Err(format!(
            "spec '{}': stream_inventory needs a deploy policy",
            spec.name
        ));
    }
    let mut out = Vec::with_capacity(spec.events.len());
    let mut ordinal = 0u64;
    for te in &spec.events {
        let Event::StreamInventory {
            count,
            every,
            expire_after,
            seed,
        } = &te.event
        else {
            out.push(te.clone());
            continue;
        };
        let mut rng = Rng::new(0xD3B1_0C ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        for i in 0..*count {
            let at = te.at + i * every;
            let bi = rng.below(world.k());
            let mult = 0.5 + 1.5 * rng.f64();
            let quality = 0.35 + 0.6 * rng.f64();
            if at >= total {
                // keep drawing order stable, drop the off-run tail
                continue;
            }
            let ws = &world.models[bi];
            let name = format!("{}@s{ordinal}", ws.name);
            ordinal += 1;
            out.push(TimedEvent {
                at,
                event: Event::OfferModel {
                    model: name.clone(),
                    price_in: Some(ws.price_in_per_m * mult),
                    price_out: Some(ws.price_out_per_m * mult),
                    quality: Some(quality),
                },
            });
            if let Some(exp) = expire_after {
                let ex = at + exp;
                if ex < total {
                    out.push(TimedEvent {
                        at: ex,
                        event: Event::ExpireModel { model: name },
                    });
                }
            }
        }
    }
    out.sort_by_key(|e| e.at); // stable: offers keep arrival order per step
    Ok(out)
}

/// Execute the manager's registry actions against an in-process host.
fn exec_deploy_actions(
    mgr: &mut SlotManager,
    actions: Vec<DeployAction>,
    router: &mut PolicyHost,
) {
    for a in actions {
        match a {
            DeployAction::Deploy(c) => {
                match router.try_add_model(
                    &c.name,
                    c.price_in,
                    c.price_out,
                    Some((DEPLOY_PRIOR_N_EFF, c.quality)),
                ) {
                    Some(slot) => mgr.note_deployed(&c.name, slot),
                    None => mgr.deploy_failed(&c.name),
                }
            }
            DeployAction::Evict { slot, .. } => {
                router.delete_model(slot);
            }
        }
    }
}

/// Environment-side multiplier for a `set_price` event: explicit `mult`,
/// else the blended-rate ratio of the event's explicit prices to the
/// world's list prices.
fn price_mult(world: &World, wi: usize, mult: Option<f64>, pi: Option<f64>, po: Option<f64>) -> f64 {
    match (mult, pi, po) {
        (Some(m), _, _) => m,
        (None, Some(pi), Some(po)) => {
            ((pi + po) / 2.0 / 1000.0) / world.models[wi].blended_per_1k()
        }
        _ => 1.0, // unreachable: Event::from_json enforces mult or both prices
    }
}

/// Apply one engine-side event to an in-process hosted policy (+ the env
/// view).
#[allow(clippy::too_many_arguments)]
fn apply_in_process(
    ev: &Event,
    world: &World,
    view: &mut EnvView,
    router: &mut PolicyHost,
    last_snapshot: &mut Option<Json>,
    deploy: &mut Option<SlotManager>,
    cand_blend: &mut HashMap<String, f64>,
    opts: &RunOptions,
) -> Result<(), String> {
    match ev {
        Event::SetPrice {
            model,
            mult,
            price_in,
            price_out,
        } => {
            let wi = world_index(world, model)?;
            let m = price_mult(world, wi, *mult, *price_in, *price_out);
            view.price_mult[wi] = m;
            if opts.reprice_router {
                if let Some(slot) = router.registry().find(model) {
                    let ws = &world.models[wi];
                    router.reprice(
                        slot,
                        price_in.unwrap_or(ws.price_in_per_m * m),
                        price_out.unwrap_or(ws.price_out_per_m * m),
                    );
                }
            }
            Ok(())
        }
        Event::DegradeQuality { model, mean_to } => {
            let wi = world_index(world, model)?;
            view.reward_mean_to[wi] = *mean_to;
            Ok(())
        }
        Event::AddModel {
            model,
            price_in,
            price_out,
            n_eff,
            r0,
        } => {
            let wi = world_index(world, model)?;
            let ws = &world.models[wi];
            router
                .try_add_model(
                    model,
                    price_in.unwrap_or(ws.price_in_per_m),
                    price_out.unwrap_or(ws.price_out_per_m),
                    n_eff.zip(*r0),
                )
                .map(|_| ())
                .ok_or_else(|| format!("add_model: '{model}' is already active"))
        }
        Event::RemoveModel { model } => {
            let slot = router
                .registry()
                .find(model)
                .ok_or_else(|| format!("remove_model: no active model '{model}'"))?;
            router.delete_model(slot);
            Ok(())
        }
        Event::SetBudget { budget } => {
            if router.set_budget(*budget) {
                Ok(())
            } else {
                Err("set_budget: router has no pacer".to_string())
            }
        }
        Event::Snapshot { path } => {
            let st = router.export_state();
            if let Some(p) = path {
                snapshot::save_value(Path::new(p), Some(router.kind()), &st)?;
            }
            *last_snapshot = Some(st);
            Ok(())
        }
        Event::Restart { path } => {
            let st = match path {
                Some(p) => {
                    let (tag, st) = snapshot::load_value(Path::new(p))?;
                    if let Some(tag) = tag {
                        if tag != router.kind() {
                            return Err(format!(
                                "restart: snapshot holds policy '{tag}' but the run uses '{}'",
                                router.kind()
                            ));
                        }
                    }
                    st
                }
                None => last_snapshot
                    .clone()
                    .ok_or("restart: no snapshot taken yet")?,
            };
            router.restore_state(&st)
        }
        Event::OfferModel {
            model,
            price_in,
            price_out,
            quality,
        } => {
            let mgr = deploy
                .as_mut()
                .ok_or("offer_model: the spec names no deploy policy")?;
            let (pi, po) = match (price_in, price_out) {
                (Some(pi), Some(po)) => (*pi, *po),
                _ => {
                    let wi = world_base_index(world, model)
                        .map_err(|e| format!("offer_model: {e}"))?;
                    (world.models[wi].price_in_per_m, world.models[wi].price_out_per_m)
                }
            };
            cand_blend.insert(model.clone(), (pi + po) / 2.0 / 1000.0);
            mgr.offer(model, pi, po, *quality);
            Ok(())
        }
        Event::ExpireModel { model } => {
            let mgr = deploy
                .as_mut()
                .ok_or("expire_model: the spec names no deploy policy")?;
            let actions = mgr.expire(model);
            exec_deploy_actions(mgr, actions, router);
            Ok(())
        }
        Event::SetSlots { k } => {
            let mgr = deploy
                .as_mut()
                .ok_or("set_slots: the spec names no deploy policy")?;
            mgr.set_slots(*k);
            Ok(())
        }
        Event::StreamInventory { .. } => {
            Err("stream_inventory must be expanded before execution".to_string())
        }
        Event::TrafficMix { .. } => Ok(()), // consumed by the planner
    }
}

/// Discard a wire call's payload, keeping only success/error.
fn wire<T>(e: Result<T, crate::client::ClientError>) -> Result<(), String> {
    e.map(|_| ()).map_err(|e| e.to_string())
}

/// Apply one engine-side event over the wire (+ the local env view).
fn apply_wire(
    ev: &Event,
    world: &World,
    view: &mut EnvView,
    client: &mut ParetoClient,
    cand_blend: &mut HashMap<String, f64>,
    opts: &RunOptions,
) -> Result<(), String> {
    match ev {
        Event::SetPrice {
            model,
            mult,
            price_in,
            price_out,
        } => {
            let wi = world_index(world, model)?;
            let m = price_mult(world, wi, *mult, *price_in, *price_out);
            view.price_mult[wi] = m;
            if !opts.reprice_router {
                // a price-blind condition: the market drifts (view) but
                // the engine keeps its frozen c̃ snapshot
                return Ok(());
            }
            let ws = &world.models[wi];
            // the engine cannot see the simulator, so the injected event
            // always carries the resolved list prices
            wire(client.inject(&Event::SetPrice {
                model: model.clone(),
                mult: None,
                price_in: Some(price_in.unwrap_or(ws.price_in_per_m * m)),
                price_out: Some(price_out.unwrap_or(ws.price_out_per_m * m)),
            }))
        }
        Event::DegradeQuality { model, mean_to } => {
            let wi = world_index(world, model)?;
            view.reward_mean_to[wi] = *mean_to;
            Ok(())
        }
        Event::AddModel {
            model,
            price_in,
            price_out,
            n_eff,
            r0,
        } => {
            let wi = world_index(world, model)?;
            let ws = &world.models[wi];
            wire(client.inject(&Event::AddModel {
                model: model.clone(),
                price_in: Some(price_in.unwrap_or(ws.price_in_per_m)),
                price_out: Some(price_out.unwrap_or(ws.price_out_per_m)),
                n_eff: *n_eff,
                r0: *r0,
            }))
        }
        Event::RemoveModel { .. } | Event::SetBudget { .. } => wire(client.inject(ev)),
        Event::Snapshot { path } => match path {
            Some(p) => wire(client.snapshot(p)),
            None => Err("snapshot: a wire-driven snapshot needs a path".to_string()),
        },
        Event::Restart { path } => match path {
            Some(p) => wire(client.restore(p)),
            None => Err("restart: a wire-driven restart needs a path".to_string()),
        },
        Event::OfferModel {
            model,
            price_in,
            price_out,
            quality,
        } => {
            // the engine cannot see the simulator: offers always carry
            // resolved prices over the wire
            let (pi, po) = match (price_in, price_out) {
                (Some(pi), Some(po)) => (*pi, *po),
                _ => {
                    let wi = world_base_index(world, model)
                        .map_err(|e| format!("offer_model: {e}"))?;
                    (world.models[wi].price_in_per_m, world.models[wi].price_out_per_m)
                }
            };
            cand_blend.insert(model.clone(), (pi + po) / 2.0 / 1000.0);
            wire(client.offer_model(model, pi, po, *quality))
        }
        Event::ExpireModel { .. } | Event::SetSlots { .. } => wire(client.inject(ev)),
        Event::StreamInventory { .. } => {
            Err("stream_inventory must be expanded before execution".to_string())
        }
        Event::TrafficMix { .. } => Ok(()),
    }
}

/// Execute a scenario in-process against a hosted policy.
///
/// The policy is driven exactly like the paper harness drives one:
/// route → realised (reward, cost) from the drifted world view → feedback
/// — with scheduled events applied *before* the routing decision of
/// their step.
pub fn run_scenario(
    spec: &ScenarioSpec,
    env: &ExpEnv,
    world: &World,
    router: &mut PolicyHost,
    opts: &RunOptions,
) -> Result<ScenarioRun, String> {
    let segments = plan_segments(spec, env, opts.seed)?;
    let total: u64 = segments.iter().map(|s| s.len() as u64).sum();
    let events = expand_events(spec, world, total)?;
    let mut deploy: Option<SlotManager> = match &spec.deploy {
        Some(d) => Some(
            build_deploy(d, spec.slots).map_err(|e| format!("spec '{}': {e}", spec.name))?,
        ),
        None => None,
    };
    let mut cand_blend: HashMap<String, f64> = HashMap::new();
    let mut view = EnvView::normal(world.k());
    let mut last_snapshot: Option<Json> = None;
    let mut event_log = Vec::new();
    let mut phases = Vec::with_capacity(segments.len());
    let mut pending: &[TimedEvent] = &events;
    let mut t = 0u64;
    for seg in &segments {
        let mut log = Vec::with_capacity(seg.len());
        for &pid in seg {
            while let Some(te) = pending.first() {
                if te.at > t {
                    break;
                }
                apply_in_process(
                    &te.event,
                    world,
                    &mut view,
                    router,
                    &mut last_snapshot,
                    &mut deploy,
                    &mut cand_blend,
                    opts,
                )
                .map_err(|e| format!("spec '{}' t={}: {e}", spec.name, te.at))?;
                event_log.push(format!("t={} {}", te.at, te.event));
                pending = &pending[1..];
            }
            let p = env.corpus.prompt(pid);
            let x = &env.contexts[pid as usize];
            let d = router.route(x);
            let name = router
                .registry()
                .get(d.arm)
                .map(|e| e.name.clone())
                .ok_or_else(|| format!("t={t}: routed to retired slot {}", d.arm))?;
            let wm = world_model_of(world, &name).map_err(|e| format!("t={t}: {e}"))?;
            let reward = world.reward_view(p, wm, &view);
            let cost = judged_cost(world, p, wm, &view, &name, &cand_blend);
            router.feedback(d.arm, x, reward, cost);
            log.push(StepLog {
                prompt: pid,
                arm: d.arm,
                reward,
                cost,
                lambda: router.lambda(),
            });
            // the deployment layer ticks once per step, after feedback:
            // offers pooled at step t reach the registry before step t+1
            if let Some(mgr) = deploy.as_mut() {
                mgr.record_stats(router.slot_stats());
                let actions = mgr.tick();
                exec_deploy_actions(mgr, actions, router);
            }
            t += 1;
        }
        phases.push(log);
    }
    apply_trailing_events(spec, &mut pending, t, &mut event_log, |ev| {
        apply_in_process(
            ev,
            world,
            &mut view,
            router,
            &mut last_snapshot,
            &mut deploy,
            &mut cand_blend,
            opts,
        )
    })?;
    Ok(ScenarioRun { phases, event_log })
}

/// Fire events scheduled exactly at the end of the run (e.g. a final
/// snapshot after the last request); anything scheduled later is a spec
/// error rather than a silent no-op.
fn apply_trailing_events(
    spec: &ScenarioSpec,
    pending: &mut &[TimedEvent],
    t_end: u64,
    event_log: &mut Vec<String>,
    mut apply: impl FnMut(&Event) -> Result<(), String>,
) -> Result<(), String> {
    loop {
        // copy the shared slice ref out of the &mut so the iteration
        // borrow does not pin `*pending` across the reassignment
        let cur = *pending;
        let Some(te) = cur.first() else { return Ok(()) };
        if te.at > t_end {
            return Err(format!(
                "spec '{}': event at {} is beyond the run ({t_end} steps)",
                spec.name, te.at
            ));
        }
        apply(&te.event).map_err(|e| format!("spec '{}' t={}: {e}", spec.name, te.at))?;
        event_log.push(format!("t={} {}", te.at, te.event));
        *pending = &cur[1..];
    }
}

/// Execute a scenario against a live server/engine over protocol v2.
///
/// Request ids are the global step numbers; rewards and costs come from
/// the local simulator (the engine serves, the world judges).
pub fn run_scenario_wire(
    spec: &ScenarioSpec,
    env: &ExpEnv,
    world: &World,
    client: &mut ParetoClient,
    opts: &RunOptions,
) -> Result<ScenarioRun, String> {
    let segments = plan_segments(spec, env, opts.seed)?;
    let total: u64 = segments.iter().map(|s| s.len() as u64).sum();
    let events = expand_events(spec, world, total)?;
    let mut cand_blend: HashMap<String, f64> = HashMap::new();
    let mut view = EnvView::normal(world.k());
    let mut event_log = Vec::new();
    let mut phases = Vec::with_capacity(segments.len());
    let mut pending: &[TimedEvent] = &events;
    let mut t = 0u64;
    for seg in &segments {
        let mut log = Vec::with_capacity(seg.len());
        for &pid in seg {
            while let Some(te) = pending.first() {
                if te.at > t {
                    break;
                }
                apply_wire(&te.event, world, &mut view, client, &mut cand_blend, opts)
                    .map_err(|e| format!("spec '{}' t={}: {e}", spec.name, te.at))?;
                event_log.push(format!("t={} {}", te.at, te.event));
                pending = &pending[1..];
            }
            let p = env.corpus.prompt(pid);
            let routed = client
                .route(t, &p.text)
                .map_err(|e| format!("route t={t}: {e}"))?;
            // judge the model the engine *named*, not the raw slot id —
            // after hot-swap churn the two disagree
            let wm = world_model_of(world, &routed.model).map_err(|e| format!("t={t}: {e}"))?;
            let reward = world.reward_view(p, wm, &view);
            let cost = judged_cost(world, p, wm, &view, &routed.model, &cand_blend);
            client
                .feedback(t, reward, cost)
                .map_err(|e| format!("feedback t={t}: {e}"))?;
            log.push(StepLog {
                prompt: pid,
                arm: routed.arm,
                reward,
                cost,
                lambda: routed.lambda,
            });
            t += 1;
        }
        phases.push(log);
    }
    apply_trailing_events(spec, &mut pending, t, &mut event_log, |ev| {
        apply_wire(ev, world, &mut view, client, &mut cand_blend, opts)
    })?;
    Ok(ScenarioRun { phases, event_log })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::FlashScenario;

    /// Small paced router over the first k world models (cold start).
    fn router(env: &ExpEnv, k: usize, budget: f64, seed: u64) -> PolicyHost {
        use crate::router::{ParetoRouter, Prior};
        let cfg = crate::router::RouterConfig::tabula_rasa(env.d(), Some(budget), seed);
        let mut r = ParetoRouter::new(cfg);
        for m in 0..k {
            let ws = &env.world.models[m];
            r.add_model(ws.name, ws.price_in_per_m, ws.price_out_per_m, Prior::Cold);
        }
        PolicyHost::new(Box::new(r), None)
    }

    /// Per-arm observation count on the hosted ParetoRouter.
    fn n_obs(host: &PolicyHost, arm: usize) -> u64 {
        host.policy_as::<crate::router::ParetoRouter>()
            .expect("pareto policy")
            .arm(arm)
            .unwrap()
            .n_obs
    }

    fn mini_spec(extra_events: &str) -> ScenarioSpec {
        ScenarioSpec::from_toml(&format!(
            r#"
[scenario]
name = "mini"
steps = 120
k = 3
stream_seed = 9000
replay_salt = 4242

[[event]]
at = 40
op = "traffic_mix"
stream = "fresh"

[[event]]
at = 40
op = "set_price"
model = "gemini-2.5-pro"
mult = 0.5

[[event]]
at = 80
op = "traffic_mix"
stream = "replay"
phase = 0
{extra_events}
"#
        ))
        .unwrap()
    }

    #[test]
    fn same_spec_and_seed_replays_bit_identically() {
        let env = ExpEnv::load(FlashScenario::GoodCheap);
        let spec = mini_spec("");
        let opts = RunOptions {
            seed: 7,
            reprice_router: true,
        };
        let mut r1 = router(&env, 3, 6.6e-4, 7);
        let mut r2 = router(&env, 3, 6.6e-4, 7);
        let a = run_scenario(&spec, &env, &env.world, &mut r1, &opts).unwrap();
        let b = run_scenario(&spec, &env, &env.world, &mut r2, &opts).unwrap();
        assert_eq!(a.event_log, b.event_log);
        assert_eq!(a.phases, b.phases, "same spec + seed must replay exactly");
        assert_eq!(a.phases.len(), 3);
        for ph in &a.phases {
            assert_eq!(ph.len(), 40);
        }
        // a different seed draws a different stream
        let mut r3 = router(&env, 3, 6.6e-4, 7);
        let c = run_scenario(
            &spec,
            &env,
            &env.world,
            &mut r3,
            &RunOptions {
                seed: 8,
                reprice_router: true,
            },
        )
        .unwrap();
        assert_ne!(a.phases, c.phases);
    }

    #[test]
    fn replay_segment_reuses_phase0_prompts() {
        let env = ExpEnv::load(FlashScenario::GoodCheap);
        let spec = mini_spec("");
        let opts = RunOptions {
            seed: 3,
            reprice_router: false,
        };
        let mut r = router(&env, 3, 6.6e-4, 3);
        let run = run_scenario(&spec, &env, &env.world, &mut r, &opts).unwrap();
        let ids = |ph: &[StepLog]| {
            let mut v: Vec<u32> = ph.iter().map(|s| s.prompt).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(ids(&run.phases[0]), ids(&run.phases[2]), "within-subject replay");
        assert_ne!(ids(&run.phases[0]), ids(&run.phases[1]));
    }

    #[test]
    fn snapshot_then_restart_rewinds_the_learned_state() {
        let env = ExpEnv::load(FlashScenario::GoodCheap);
        let spec = mini_spec(
            r#"
[[event]]
at = 60
op = "snapshot"

[[event]]
at = 100
op = "restart"
"#,
        );
        let opts = RunOptions {
            seed: 11,
            reprice_router: false,
        };
        let mut r = router(&env, 3, 6.6e-4, 11);
        let run = run_scenario(&spec, &env, &env.world, &mut r, &opts).unwrap();
        assert_eq!(run.phases.iter().map(Vec::len).sum::<usize>(), 120);
        assert!(run
            .event_log
            .iter()
            .any(|l| l.starts_with("t=60") && l.contains("snapshot")));
        assert!(run
            .event_log
            .iter()
            .any(|l| l.starts_with("t=100") && l.contains("restart")));
        // the restart rewound the router clock to the snapshot step (60)
        // and then served the remaining 20 requests
        assert_eq!(r.step(), 80);
        assert_eq!(n_obs(&r, 0) + n_obs(&r, 1) + n_obs(&r, 2), 80);
    }

    #[test]
    fn restart_without_snapshot_is_an_error() {
        let env = ExpEnv::load(FlashScenario::GoodCheap);
        let spec = mini_spec(
            r#"
[[event]]
at = 50
op = "restart"
"#,
        );
        let mut r = router(&env, 3, 6.6e-4, 1);
        let e = run_scenario(
            &spec,
            &env,
            &env.world,
            &mut r,
            &RunOptions {
                seed: 1,
                reprice_router: false,
            },
        )
        .unwrap_err();
        assert!(e.contains("no snapshot"), "{e}");
    }

    #[test]
    fn hot_swap_churn_remove_then_readd_gets_a_fresh_slot() {
        let env = ExpEnv::load(FlashScenario::GoodCheap);
        // two full remove -> re-add cycles: the second lands on slot 4,
        // PAST the world bank's k=4 — the executor must judge rewards by
        // the slot's registered NAME, not by the raw slot id (which
        // would read the wrong world model, or index out of bounds)
        let spec = mini_spec(
            r#"
[[event]]
at = 50
op = "remove_model"
model = "mistral-large"

[[event]]
at = 90
op = "add_model"
model = "mistral-large"

[[event]]
at = 100
op = "remove_model"
model = "mistral-large"

[[event]]
at = 110
op = "add_model"
model = "mistral-large"
"#,
        );
        let mut r = router(&env, 3, 6.6e-4, 5);
        let run = run_scenario(
            &spec,
            &env,
            &env.world,
            &mut r,
            &RunOptions {
                seed: 5,
                reprice_router: false,
            },
        )
        .unwrap();
        // tombstoned slots are never reused: each re-add lands on a
        // fresh id and the name resolves to the latest one
        assert_eq!(r.registry().n_slots(), 5);
        assert!(!r.registry().is_active(1));
        assert!(!r.registry().is_active(3));
        assert_eq!(r.registry().find("mistral-large"), Some(4));
        // no routing step inside a removal window picked a tombstone
        let flat = run.flat();
        assert!(flat[50..90].iter().all(|s| s.arm != 1));
        assert!(flat[100..110].iter().all(|s| s.arm != 1 && s.arm != 3));
        // the re-added model's logged rewards are mistral-large's world
        // profile: burn-in forces slot-4 pulls right after t=110, and a
        // name-correct mapping keeps them at mistral-like quality
        let readded: Vec<f64> = flat[110..]
            .iter()
            .filter(|s| s.arm == 4)
            .map(|s| s.reward)
            .collect();
        assert!(!readded.is_empty(), "burn-in must route the re-added slot");
        let mean = readded.iter().sum::<f64>() / readded.len() as f64;
        assert!(mean > 0.6, "slot 4 must be judged as mistral-large, got {mean}");
    }

    #[test]
    fn add_of_an_active_name_fails_with_a_timeline_error() {
        let env = ExpEnv::load(FlashScenario::GoodCheap);
        let spec = mini_spec(
            r#"
[[event]]
at = 50
op = "add_model"
model = "mistral-large"
"#,
        );
        let mut r = router(&env, 3, 6.6e-4, 5);
        let e = run_scenario(
            &spec,
            &env,
            &env.world,
            &mut r,
            &RunOptions {
                seed: 5,
                reprice_router: false,
            },
        )
        .unwrap_err();
        assert!(e.contains("already active"), "{e}");
        assert!(e.contains("t=50"), "{e}");
    }

    #[test]
    fn streaming_inventory_respects_the_slot_cap_and_replays_identically() {
        let env = ExpEnv::load(FlashScenario::GoodCheap);
        let spec = ScenarioSpec::from_toml(
            r#"
[scenario]
name = "stream-mini"
steps = 160
k = 3
deploy = "ucb:16"
slots = 2

[[event]]
at = 0
op = "stream_inventory"
count = 12
every = 8
expire_after = 48
seed = 7
"#,
        )
        .unwrap();
        let opts = RunOptions {
            seed: 9,
            reprice_router: false,
        };
        let mut r1 = router(&env, 3, 6.6e-4, 9);
        let mut r2 = router(&env, 3, 6.6e-4, 9);
        let a = run_scenario(&spec, &env, &env.world, &mut r1, &opts).unwrap();
        let b = run_scenario(&spec, &env, &env.world, &mut r2, &opts).unwrap();
        assert_eq!(a.event_log, b.event_log, "expansion must be seed-stable");
        assert_eq!(a.phases, b.phases, "streaming runs must replay bit-identically");
        // the generator expanded into synthesized offers and expires
        assert!(a.event_log.iter().any(|l| l.contains("offer_model")));
        assert!(a.event_log.iter().any(|l| l.contains("expire_model")));
        // manager-deployed candidates never exceed the 2-slot cap on top
        // of the 3-model initial portfolio
        let active = r1.registry().n_active();
        assert!(active <= 5, "slot cap breached: {active} active");
        // churn happened: candidates were deployed onto fresh slots
        assert!(r1.registry().n_slots() > 3, "no candidate was ever deployed");
    }

    #[test]
    fn deploy_verbs_without_a_deploy_policy_are_an_error() {
        let env = ExpEnv::load(FlashScenario::GoodCheap);
        let spec = ScenarioSpec::from_toml(
            r#"
[scenario]
name = "no-deploy"
steps = 40
k = 3

[[event]]
at = 10
op = "offer_model"
model = "mistral-large@s0"
price_in = 0.4
price_out = 1.6
"#,
        )
        .unwrap();
        let mut r = router(&env, 3, 6.6e-4, 2);
        let e = run_scenario(
            &spec,
            &env,
            &env.world,
            &mut r,
            &RunOptions {
                seed: 2,
                reprice_router: false,
            },
        )
        .unwrap_err();
        assert!(e.contains("no deploy policy"), "{e}");
    }

    #[test]
    fn planner_rejects_malformed_timelines() {
        let env = ExpEnv::load(FlashScenario::GoodCheap);
        let over = ScenarioSpec::from_toml(
            "[scenario]\nname = \"x\"\nsteps = 999999\n",
        )
        .unwrap();
        assert!(plan_segments(&over, &env, 1).unwrap_err().contains("split"));
        let bad_replay = ScenarioSpec::from_toml(
            "[scenario]\nname = \"x\"\nsteps = 40\n\n[[event]]\nat = 20\nop = \"traffic_mix\"\nstream = \"replay\"\nphase = 9\n",
        )
        .unwrap();
        assert!(plan_segments(&bad_replay, &env, 1)
            .unwrap_err()
            .contains("unknown phase"));
    }
}
