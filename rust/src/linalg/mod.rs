//! Small dense linear algebra for the LinUCB hot path.
//!
//! Everything the router needs is `O(d^2)` per request at `d = 26`: cached
//! inverses, Sherman–Morrison rank-1 corrections, quadratic forms and
//! mat-vec products.  Each arm additionally maintains a running Cholesky
//! factor of its design matrix through O(d²) rank-1 up/downdates
//! ([`Cholesky::rank1_update`] / [`Cholesky::rank1_downdate`]), with a
//! periodic exact refactorization bounding the drift of both the factor
//! and the Sherman–Morrison inverse cache; a plain Gauss–Jordan inversion
//! exists solely as the paper's Table-10 baseline.
//!
//! The kernels here are written so the scalar compiler auto-vectorizes
//! them (`BENCH_routing.json` tracks the effect): [`dot`] splits into four
//! independent accumulators and [`Mat::quad_form`] reads only the upper
//! triangle of its symmetric argument — the same shapes the Pallas
//! `ucb_score` kernel (`python/compile/kernels/ucb_score.py`) uses on the
//! accelerator side.

mod chol;
mod mat;

pub use chol::Cholesky;
pub use mat::Mat;

/// Dot product, unrolled into four independent accumulators so the
/// compiler can keep multiple FMAs in flight (a single running sum
/// serializes on the add latency).  Summation order is fixed —
/// `(s0+s1)+(s2+s3)` over the lanes, then the tail — so results stay
/// bit-reproducible across runs on the same target.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let mut chunks_a = a.chunks_exact(4);
    let mut chunks_b = b.chunks_exact(4);
    for (ca, cb) in chunks_a.by_ref().zip(chunks_b.by_ref()) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (x, y) in chunks_a.remainder().iter().zip(chunks_b.remainder()) {
        s += x * y;
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// L2 norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn dot_unrolled_covers_all_remainders() {
        // exercise every lane/tail split: lengths 0..=9
        for n in 0..=9usize {
            let a: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5 - 1.0).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12, "n={n}");
        }
    }
}
