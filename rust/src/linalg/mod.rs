//! Small dense linear algebra for the LinUCB hot path.
//!
//! Everything the router needs is `O(d^2)` per request at `d = 26`: cached
//! inverses, Sherman–Morrison rank-1 corrections, quadratic forms and
//! mat-vec products.  A Cholesky solver backs prior fitting and the
//! periodic inverse refresh that bounds Sherman–Morrison drift; a plain
//! Gauss–Jordan inversion exists solely as the paper's Table-10 baseline.

mod chol;
mod mat;

pub use chol::Cholesky;
pub use mat::Mat;

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// L2 norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
