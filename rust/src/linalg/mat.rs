//! Row-major square matrix with the operations the bandit hot path needs.

use super::dot;

/// Dense square matrix, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    d: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(d: usize) -> Mat {
        Mat {
            d,
            data: vec![0.0; d * d],
        }
    }

    /// lambda * I
    pub fn scaled_identity(d: usize, lambda: f64) -> Mat {
        let mut m = Mat::zeros(d);
        for i in 0..d {
            m.data[i * d + i] = lambda;
        }
        m
    }

    pub fn from_rows(d: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), d * d);
        Mat { d, data }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.d + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.d + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// self *= s (every entry).
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// self += s * I
    pub fn add_diag(&mut self, s: f64) {
        for i in 0..self.d {
            self.data[i * self.d + i] += s;
        }
    }

    /// self += c * x xᵀ  (rank-1 update).
    pub fn add_outer(&mut self, c: f64, x: &[f64]) {
        debug_assert_eq!(x.len(), self.d);
        let d = self.d;
        for i in 0..d {
            let cxi = c * x[i];
            let row = &mut self.data[i * d..(i + 1) * d];
            for j in 0..d {
                row[j] += cxi * x[j];
            }
        }
    }

    /// self += c * other
    pub fn add_scaled(&mut self, c: f64, other: &Mat) {
        debug_assert_eq!(self.d, other.d);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += c * b;
        }
    }

    /// y = A x
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.d);
        debug_assert_eq!(y.len(), self.d);
        for i in 0..self.d {
            y[i] = dot(self.row(i), x);
        }
    }

    /// xᵀ A x  (A assumed symmetric).  Reads only the diagonal + upper
    /// triangle — each off-diagonal pair contributes `2·x_i·a_ij·x_j` —
    /// which halves the memory traffic of the dense row sweep; the same
    /// shape the Pallas `ucb_score` kernel uses for the exploration
    /// bonus.  The tiny asymmetry Sherman–Morrison round-off leaves in
    /// the cached inverse (~1 ulp) is averaged out by the periodic exact
    /// refresh, far below the routing tolerances.
    #[inline]
    pub fn quad_form(&self, x: &[f64]) -> f64 {
        let d = self.d;
        let mut diag = 0.0;
        let mut off = 0.0;
        for i in 0..d {
            let row = &self.data[i * d..(i + 1) * d];
            diag += x[i] * x[i] * row[i];
            off += x[i] * dot(&row[i + 1..], &x[i + 1..]);
        }
        diag + 2.0 * off
    }

    /// Sherman–Morrison: given self = A⁻¹, update in place to (A + x xᵀ)⁻¹.
    /// Returns xᵀ A⁻¹ x (useful to the caller).  O(d²).
    pub fn sherman_morrison_update(&mut self, x: &[f64], scratch: &mut [f64]) -> f64 {
        let d = self.d;
        debug_assert_eq!(x.len(), d);
        debug_assert_eq!(scratch.len(), d);
        // u = A⁻¹ x  (A⁻¹ symmetric)
        self.matvec(x, scratch);
        let denom = 1.0 + dot(x, scratch);
        let quad = denom - 1.0;
        let c = 1.0 / denom;
        for i in 0..d {
            let ci = c * scratch[i];
            let row = &mut self.data[i * d..(i + 1) * d];
            for j in 0..d {
                row[j] -= ci * scratch[j];
            }
        }
        quad
    }

    /// Sherman–Morrison removal: given self = A⁻¹, update in place to
    /// (A − x xᵀ)⁻¹.  Returns `None` — with self UNCHANGED — when
    /// `1 − xᵀA⁻¹x` is not safely positive, i.e. removing x would
    /// (numerically) destroy positive definiteness; otherwise returns
    /// xᵀ A⁻¹ x.  O(d²).  The inverse-cache counterpart of
    /// [`crate::linalg::Cholesky::rank1_downdate`].
    pub fn sherman_morrison_downdate(&mut self, x: &[f64], scratch: &mut [f64]) -> Option<f64> {
        let d = self.d;
        debug_assert_eq!(x.len(), d);
        debug_assert_eq!(scratch.len(), d);
        // u = A⁻¹ x  (A⁻¹ symmetric)
        self.matvec(x, scratch);
        let quad = dot(x, scratch);
        let denom = 1.0 - quad;
        if denom <= 1e-12 {
            return None;
        }
        let c = 1.0 / denom;
        for i in 0..d {
            let ci = c * scratch[i];
            let row = &mut self.data[i * d..(i + 1) * d];
            for j in 0..d {
                row[j] += ci * scratch[j];
            }
        }
        Some(quad)
    }

    /// Full Gauss–Jordan inversion with partial pivoting.  O(d³).
    /// The paper's Table-10 baseline ("Cached Inv." / "Per-Route Inv.").
    pub fn inverse_gauss_jordan(&self) -> Option<Mat> {
        let d = self.d;
        let mut a = self.data.clone();
        let mut inv = Mat::scaled_identity(d, 1.0).data;
        for col in 0..d {
            // pivot
            let mut piv = col;
            let mut best = a[col * d + col].abs();
            for r in (col + 1)..d {
                let v = a[r * d + col].abs();
                if v > best {
                    best = v;
                    piv = r;
                }
            }
            if best < 1e-300 {
                return None;
            }
            if piv != col {
                for j in 0..d {
                    a.swap(col * d + j, piv * d + j);
                    inv.swap(col * d + j, piv * d + j);
                }
            }
            let p = a[col * d + col];
            let pinv = 1.0 / p;
            for j in 0..d {
                a[col * d + j] *= pinv;
                inv[col * d + j] *= pinv;
            }
            for r in 0..d {
                if r == col {
                    continue;
                }
                let f = a[r * d + col];
                if f == 0.0 {
                    continue;
                }
                for j in 0..d {
                    a[r * d + j] -= f * a[col * d + j];
                    inv[r * d + j] -= f * inv[col * d + j];
                }
            }
        }
        Some(Mat { d, data: inv })
    }

    /// Max |self - other| entry.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn identity_and_scale() {
        let mut m = Mat::scaled_identity(3, 2.0);
        assert_eq!(m.at(0, 0), 2.0);
        assert_eq!(m.at(0, 1), 0.0);
        m.scale(0.5);
        assert_eq!(m.at(2, 2), 1.0);
    }

    #[test]
    fn outer_product_update() {
        let mut m = Mat::zeros(2);
        m.add_outer(2.0, &[1.0, 3.0]);
        assert_eq!(m.at(0, 0), 2.0);
        assert_eq!(m.at(0, 1), 6.0);
        assert_eq!(m.at(1, 0), 6.0);
        assert_eq!(m.at(1, 1), 18.0);
    }

    #[test]
    fn quad_form_matches_matvec() {
        let mut rng = Rng::new(1);
        let d = 5;
        let a = Mat::from_rows(d, prop::spd(&mut rng, d, 0.5));
        let x = prop::vec_f64(&mut rng, d, 2.0);
        let mut y = vec![0.0; d];
        a.matvec(&x, &mut y);
        assert!((a.quad_form(&x) - dot(&x, &y)).abs() < 1e-10);
    }

    #[test]
    fn gauss_jordan_inverts() {
        let mut rng = Rng::new(2);
        let d = 8;
        let a = Mat::from_rows(d, prop::spd(&mut rng, d, 1.0));
        let inv = a.inverse_gauss_jordan().unwrap();
        // A * A⁻¹ ≈ I
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += a.at(i, k) * inv.at(k, j);
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-8, "({i},{j}) = {s}");
            }
        }
    }

    #[test]
    fn singular_returns_none() {
        let m = Mat::zeros(3);
        assert!(m.inverse_gauss_jordan().is_none());
    }

    #[test]
    fn sherman_morrison_downdate_inverts_update() {
        prop::for_cases(30, 8, |rng, _| {
            let d = 2 + rng.below(10);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let exact = a.inverse_gauss_jordan().unwrap();
            let mut inv = exact.clone();
            let x = prop::vec_f64(rng, d, 1.5);
            let mut scratch = vec![0.0; d];
            inv.sherman_morrison_update(&x, &mut scratch);
            let quad = inv.sherman_morrison_downdate(&x, &mut scratch);
            assert!(quad.is_some(), "removing what was added must succeed");
            assert!(
                inv.max_abs_diff(&exact) < 1e-7,
                "SM roundtrip drifted: {}",
                inv.max_abs_diff(&exact)
            );
        });
    }

    #[test]
    fn sherman_morrison_downdate_rejects_unabsorbed_vector() {
        // A = 0.01 I  ⇒  A⁻¹ = 100 I;  removing e₀ gives denom 1-100 < 0
        let mut inv = Mat::scaled_identity(3, 100.0);
        let before = inv.clone();
        let mut scratch = vec![0.0; 3];
        assert!(inv
            .sherman_morrison_downdate(&[1.0, 0.0, 0.0], &mut scratch)
            .is_none());
        assert_eq!(inv.max_abs_diff(&before), 0.0, "must leave self unchanged");
    }

    #[test]
    fn sherman_morrison_matches_full_inverse() {
        prop::for_cases(30, 7, |rng, _| {
            let d = 2 + rng.below(10);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let mut inv = a.inverse_gauss_jordan().unwrap();
            let x = prop::vec_f64(rng, d, 1.5);
            let mut scratch = vec![0.0; d];
            let quad = inv.sherman_morrison_update(&x, &mut scratch);
            assert!(quad >= -1e-9, "quad form must be nonneg for SPD A");
            // reference: invert (A + x xᵀ) directly
            let mut a2 = a.clone();
            a2.add_outer(1.0, &x);
            let want = a2.inverse_gauss_jordan().unwrap();
            assert!(
                inv.max_abs_diff(&want) < 1e-7,
                "SM drifted: {}",
                inv.max_abs_diff(&want)
            );
        });
    }
}
