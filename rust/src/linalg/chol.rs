//! Cholesky factorization for SPD systems.
//!
//! Backs (a) prior fitting (ridge solves over offline sufficient
//! statistics), (b) the periodic exact inverse refresh that bounds
//! Sherman–Morrison floating-point drift on long-running arms.

use super::mat::Mat;

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
pub struct Cholesky {
    d: usize,
    l: Vec<f64>, // row-major lower triangle (full square storage)
}

impl Cholesky {
    /// Factor an SPD matrix. Returns None if not positive definite.
    pub fn factor(a: &Mat) -> Option<Cholesky> {
        let d = a.dim();
        let mut l = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..=i {
                let mut s = a.at(i, j);
                for k in 0..j {
                    s -= l[i * d + k] * l[j * d + k];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[i * d + i] = s.sqrt();
                } else {
                    l[i * d + j] = s / l[j * d + j];
                }
            }
        }
        Some(Cholesky { d, l })
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let d = self.d;
        debug_assert_eq!(b.len(), d);
        // forward: L y = b
        let mut y = vec![0.0; d];
        for i in 0..d {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[i * d + k] * y[k];
            }
            y[i] = s / self.l[i * d + i];
        }
        // backward: Lᵀ x = y
        let mut x = vec![0.0; d];
        for i in (0..d).rev() {
            let mut s = y[i];
            for k in (i + 1)..d {
                s -= self.l[k * d + i] * x[k];
            }
            x[i] = s / self.l[i * d + i];
        }
        x
    }

    /// A⁻¹ via d solves against unit vectors.
    pub fn inverse(&self) -> Mat {
        let d = self.d;
        let mut inv = Mat::zeros(d);
        let mut e = vec![0.0; d];
        for j in 0..d {
            e[j] = 1.0;
            let col = self.solve(&e);
            e[j] = 0.0;
            for i in 0..d {
                *inv.at_mut(i, j) = col[i];
            }
        }
        // symmetrize to kill round-off asymmetry
        for i in 0..d {
            for j in 0..i {
                let m = 0.5 * (inv.at(i, j) + inv.at(j, i));
                *inv.at_mut(i, j) = m;
                *inv.at_mut(j, i) = m;
            }
        }
        inv
    }

    /// y = L z (action of the lower factor — Gaussian sampling).
    pub fn lower_mul(&self, z: &[f64]) -> Vec<f64> {
        let d = self.d;
        debug_assert_eq!(z.len(), d);
        let mut y = vec![0.0; d];
        for i in 0..d {
            let mut s = 0.0;
            for k in 0..=i {
                s += self.l[i * d + k] * z[k];
            }
            y[i] = s;
        }
        y
    }

    /// log det(A) = 2 Σ log L_ii
    pub fn logdet(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.d {
            s += self.l[i * self.d + i].ln();
        }
        2.0 * s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn solve_matches_direct() {
        prop::for_cases(25, 11, |rng, _| {
            let d = 2 + rng.below(12);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let b = prop::vec_f64(rng, d, 3.0);
            let ch = Cholesky::factor(&a).expect("SPD");
            let x = ch.solve(&b);
            let mut ax = vec![0.0; d];
            a.matvec(&x, &mut ax);
            for i in 0..d {
                assert!((ax[i] - b[i]).abs() < 1e-8, "residual {}", ax[i] - b[i]);
            }
        });
    }

    #[test]
    fn inverse_matches_gauss_jordan() {
        prop::for_cases(15, 12, |rng, _| {
            let d = 2 + rng.below(10);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let inv_c = Cholesky::factor(&a).unwrap().inverse();
            let inv_g = a.inverse_gauss_jordan().unwrap();
            assert!(inv_c.max_abs_diff(&inv_g) < 1e-7);
        });
    }

    #[test]
    fn rejects_indefinite() {
        let mut m = Mat::scaled_identity(3, 1.0);
        *m.at_mut(2, 2) = -1.0;
        assert!(Cholesky::factor(&m).is_none());
    }

    #[test]
    fn logdet_identity_zero() {
        let m = Mat::scaled_identity(4, 1.0);
        assert!(Cholesky::factor(&m).unwrap().logdet().abs() < 1e-12);
    }
}
