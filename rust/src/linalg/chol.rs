//! Cholesky factorization for SPD systems, with O(d²) rank-1 maintenance.
//!
//! Backs (a) prior fitting (ridge solves over offline sufficient
//! statistics), (b) the *maintained* factor of each arm's design matrix
//! `A = L Lᵀ`: every observation applies [`Cholesky::rank1_update`]
//! (O(d²)) instead of refactoring from scratch (O(d³)), geometric
//! forgetting rescales the factor via [`Cholesky::scale`], and a periodic
//! [`Cholesky::refactor`] bounds the accumulated floating-point drift
//! (see `bandit::arm` for the refresh cadence and the measured drift
//! bound).
//!
//! All hot-path entry points (`rank1_update`, `rank1_downdate`,
//! `solve_into`, `inverse_into`, `refactor` at unchanged dimension) are
//! allocation-free; the allocating `solve` / `inverse` wrappers remain
//! for cold paths like prior fitting.

use super::mat::Mat;

/// Lower-triangular Cholesky factor L with A = L Lᵀ.
#[derive(Clone, Debug)]
pub struct Cholesky {
    d: usize,
    l: Vec<f64>, // row-major lower triangle (full square storage)
}

impl Cholesky {
    /// Factor an SPD matrix. Returns None if not positive definite.
    pub fn factor(a: &Mat) -> Option<Cholesky> {
        let mut ch = Cholesky {
            d: a.dim(),
            l: vec![0.0; a.dim() * a.dim()],
        };
        if ch.refactor(a) {
            Some(ch)
        } else {
            None
        }
    }

    /// The factor of `lambda * I`: L = sqrt(lambda) * I.  The exact cold
    /// start of every arm's maintained factor (`A = λ₀I`).
    pub fn scaled_identity(d: usize, lambda: f64) -> Cholesky {
        debug_assert!(lambda > 0.0);
        let mut l = vec![0.0; d * d];
        let s = lambda.sqrt();
        for i in 0..d {
            l[i * d + i] = s;
        }
        Cholesky { d, l }
    }

    /// Refactor in place from `a`, reusing the existing storage (the
    /// periodic exact refresh — no allocation when the dimension is
    /// unchanged).  Returns `false` if `a` is not positive definite, in
    /// which case the factor is left PARTIALLY OVERWRITTEN and must not
    /// be used until a later `refactor` succeeds.
    pub fn refactor(&mut self, a: &Mat) -> bool {
        let d = a.dim();
        if self.d != d {
            self.d = d;
            self.l.resize(d * d, 0.0);
        }
        self.l.fill(0.0);
        for i in 0..d {
            for j in 0..=i {
                let mut s = a.at(i, j);
                for k in 0..j {
                    s -= self.l[i * d + k] * self.l[j * d + k];
                }
                if i == j {
                    if s <= 0.0 {
                        return false;
                    }
                    self.l[i * d + i] = s.sqrt();
                } else {
                    self.l[i * d + j] = s / self.l[j * d + j];
                }
            }
        }
        true
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    /// L_ij (zero above the diagonal).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.l[i * self.d + j]
    }

    /// Max |L_self − L_other| entry — the drift metric the rank-1
    /// property tests assert against a from-scratch factorization.
    pub fn max_abs_diff(&self, other: &Cholesky) -> f64 {
        debug_assert_eq!(self.d, other.d);
        self.l
            .iter()
            .zip(other.l.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Rank-1 UPDATE: given self = chol(A), rewrite to chol(A + x xᵀ) in
    /// O(d²) (LINPACK `dchud`-style column sweep of Givens-like
    /// rotations).  `work` is caller-provided scratch of length d; `x` is
    /// not modified.  Always succeeds: adding an outer product keeps A
    /// positive definite.
    ///
    /// Contract: each sweep is backward-stable, but drift relative to the
    /// from-scratch factor accumulates over many sweeps; callers that
    /// update in a loop must periodically [`Cholesky::refactor`] (the arm
    /// layer does so every `REFRESH_EVERY` observations, which the
    /// property tests bound at ≤1e-9 total drift).
    // lint: no_alloc
    pub fn rank1_update(&mut self, x: &[f64], work: &mut [f64]) {
        let d = self.d;
        debug_assert_eq!(x.len(), d);
        debug_assert_eq!(work.len(), d);
        work.copy_from_slice(x);
        for k in 0..d {
            let lkk = self.l[k * d + k];
            let wk = work[k];
            let r = (lkk * lkk + wk * wk).sqrt();
            let c = r / lkk;
            let s = wk / lkk;
            self.l[k * d + k] = r;
            for i in (k + 1)..d {
                let lik = (self.l[i * d + k] + s * work[i]) / c;
                work[i] = c * work[i] - s * lik;
                self.l[i * d + k] = lik;
            }
        }
    }

    /// Rank-1 DOWNDATE: given self = chol(A), rewrite to chol(A − x xᵀ)
    /// in O(d²) (hyperbolic rotations).  Returns `false` — leaving the
    /// factor PARTIALLY MODIFIED — when A − x xᵀ is not numerically
    /// positive definite, i.e. x was never absorbed into A (or drift ate
    /// the margin); the caller must then [`Cholesky::refactor`] from its
    /// exact statistics before using the factor again.  `bandit::arm`'s
    /// `retract` is the canonical caller and does exactly that.
    // lint: no_alloc
    pub fn rank1_downdate(&mut self, x: &[f64], work: &mut [f64]) -> bool {
        let d = self.d;
        debug_assert_eq!(x.len(), d);
        debug_assert_eq!(work.len(), d);
        work.copy_from_slice(x);
        for k in 0..d {
            let lkk = self.l[k * d + k];
            let wk = work[k];
            let r2 = lkk * lkk - wk * wk;
            if r2 <= 0.0 {
                return false;
            }
            let r = r2.sqrt();
            let c = r / lkk;
            let s = wk / lkk;
            self.l[k * d + k] = r;
            for i in (k + 1)..d {
                let lik = (self.l[i * d + k] - s * work[i]) / c;
                work[i] = c * work[i] - s * lik;
                self.l[i * d + k] = lik;
            }
        }
        true
    }

    /// Rescale the factored matrix: chol(A) → chol(f·A), i.e. L *= √f.
    /// This is how geometric forgetting (`A ← γ^Δt A`) propagates to the
    /// maintained factor in O(d²) without refactoring.  `f` must be > 0.
    pub fn scale(&mut self, f: f64) {
        debug_assert!(f > 0.0);
        let s = f.sqrt();
        for v in &mut self.l {
            *v *= s;
        }
    }

    /// Solve A x = b without allocating: `y` is caller scratch of length
    /// d, `x` receives the solution.  `b` may NOT alias `x` or `y`.
    // lint: no_alloc
    pub fn solve_into(&self, b: &[f64], x: &mut [f64], y: &mut [f64]) {
        let d = self.d;
        debug_assert_eq!(b.len(), d);
        debug_assert_eq!(x.len(), d);
        debug_assert_eq!(y.len(), d);
        // forward: L y = b
        for i in 0..d {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[i * d + k] * y[k];
            }
            y[i] = s / self.l[i * d + i];
        }
        // backward: Lᵀ x = y
        for i in (0..d).rev() {
            let mut s = y[i];
            for k in (i + 1)..d {
                s -= self.l[k * d + i] * x[k];
            }
            x[i] = s / self.l[i * d + i];
        }
    }

    /// Solve A x = b (allocating convenience wrapper over
    /// [`Cholesky::solve_into`]).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let d = self.d;
        let mut x = vec![0.0; d];
        let mut y = vec![0.0; d];
        self.solve_into(b, &mut x, &mut y);
        x
    }

    /// A⁻¹ into caller storage, allocation-free: d triangular solves
    /// against unit vectors, then symmetrization.  `y` and `x` are
    /// scratch of length d.  For b = e_j the forward solve yields
    /// y_i = 0 exactly for i < j, so the sweep starts at row j —
    /// bit-identical to the full solve at half the work.
    // lint: no_alloc
    pub fn inverse_into(&self, out: &mut Mat, y: &mut [f64], x: &mut [f64]) {
        let d = self.d;
        debug_assert_eq!(out.dim(), d);
        debug_assert_eq!(y.len(), d);
        debug_assert_eq!(x.len(), d);
        for j in 0..d {
            // forward: L y = e_j (rows below j only; above are exact 0)
            for v in y[..j].iter_mut() {
                *v = 0.0;
            }
            for i in j..d {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in j..i {
                    s -= self.l[i * d + k] * y[k];
                }
                y[i] = s / self.l[i * d + i];
            }
            // backward: Lᵀ x = y
            for i in (0..d).rev() {
                let mut s = y[i];
                for k in (i + 1)..d {
                    s -= self.l[k * d + i] * x[k];
                }
                x[i] = s / self.l[i * d + i];
            }
            for i in 0..d {
                *out.at_mut(i, j) = x[i];
            }
        }
        // symmetrize to kill round-off asymmetry
        for i in 0..d {
            for j in 0..i {
                let m = 0.5 * (out.at(i, j) + out.at(j, i));
                *out.at_mut(i, j) = m;
                *out.at_mut(j, i) = m;
            }
        }
    }

    /// A⁻¹ (allocating convenience wrapper over
    /// [`Cholesky::inverse_into`]).
    pub fn inverse(&self) -> Mat {
        let d = self.d;
        let mut inv = Mat::zeros(d);
        let mut y = vec![0.0; d];
        let mut x = vec![0.0; d];
        self.inverse_into(&mut inv, &mut y, &mut x);
        inv
    }

    /// y = L z (action of the lower factor — Gaussian sampling).
    pub fn lower_mul(&self, z: &[f64]) -> Vec<f64> {
        let d = self.d;
        debug_assert_eq!(z.len(), d);
        let mut y = vec![0.0; d];
        for i in 0..d {
            let mut s = 0.0;
            for k in 0..=i {
                s += self.l[i * d + k] * z[k];
            }
            y[i] = s;
        }
        y
    }

    /// log det(A) = 2 Σ log L_ii
    pub fn logdet(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.d {
            s += self.l[i * self.d + i].ln();
        }
        2.0 * s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn solve_matches_direct() {
        prop::for_cases(25, 11, |rng, _| {
            let d = 2 + rng.below(12);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let b = prop::vec_f64(rng, d, 3.0);
            let ch = Cholesky::factor(&a).expect("SPD");
            let x = ch.solve(&b);
            let mut ax = vec![0.0; d];
            a.matvec(&x, &mut ax);
            for i in 0..d {
                assert!((ax[i] - b[i]).abs() < 1e-8, "residual {}", ax[i] - b[i]);
            }
        });
    }

    #[test]
    fn inverse_matches_gauss_jordan() {
        prop::for_cases(15, 12, |rng, _| {
            let d = 2 + rng.below(10);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let inv_c = Cholesky::factor(&a).unwrap().inverse();
            let inv_g = a.inverse_gauss_jordan().unwrap();
            assert!(inv_c.max_abs_diff(&inv_g) < 1e-7);
        });
    }

    #[test]
    fn rejects_indefinite() {
        let mut m = Mat::scaled_identity(3, 1.0);
        *m.at_mut(2, 2) = -1.0;
        assert!(Cholesky::factor(&m).is_none());
    }

    #[test]
    fn logdet_identity_zero() {
        let m = Mat::scaled_identity(4, 1.0);
        assert!(Cholesky::factor(&m).unwrap().logdet().abs() < 1e-12);
    }

    #[test]
    fn scaled_identity_matches_factor() {
        for d in [1usize, 3, 7] {
            for lam in [0.05, 1.0, 42.0] {
                let direct = Cholesky::scaled_identity(d, lam);
                let via = Cholesky::factor(&Mat::scaled_identity(d, lam)).unwrap();
                assert_eq!(direct.max_abs_diff(&via), 0.0);
            }
        }
    }

    #[test]
    fn refactor_reuses_storage_across_matrices() {
        prop::for_cases(10, 13, |rng, _| {
            let d = 2 + rng.below(8);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let b = Mat::from_rows(d, prop::spd(rng, d, 0.5));
            let mut ch = Cholesky::factor(&a).unwrap();
            assert!(ch.refactor(&b));
            let fresh = Cholesky::factor(&b).unwrap();
            assert_eq!(ch.max_abs_diff(&fresh), 0.0, "refactor must be bit-identical");
        });
    }

    #[test]
    fn rank1_update_matches_refactor() {
        prop::for_cases(30, 14, |rng, _| {
            let d = 2 + rng.below(12);
            let mut a = Mat::from_rows(d, prop::spd(rng, d, 0.5));
            let mut ch = Cholesky::factor(&a).unwrap();
            let mut work = vec![0.0; d];
            for _ in 0..5 {
                let x = prop::vec_f64(rng, d, 1.5);
                a.add_outer(1.0, &x);
                ch.rank1_update(&x, &mut work);
            }
            let exact = Cholesky::factor(&a).unwrap();
            assert!(
                ch.max_abs_diff(&exact) < 1e-9,
                "update drift {}",
                ch.max_abs_diff(&exact)
            );
        });
    }

    #[test]
    fn rank1_downdate_inverts_update() {
        prop::for_cases(30, 15, |rng, _| {
            let d = 2 + rng.below(12);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let exact = Cholesky::factor(&a).unwrap();
            let mut ch = exact.clone();
            let mut work = vec![0.0; d];
            let x = prop::vec_f64(rng, d, 1.5);
            ch.rank1_update(&x, &mut work);
            assert!(ch.rank1_downdate(&x, &mut work), "must stay SPD");
            assert!(
                ch.max_abs_diff(&exact) < 1e-9,
                "roundtrip drift {}",
                ch.max_abs_diff(&exact)
            );
        });
    }

    #[test]
    fn downdate_rejects_unabsorbed_vector() {
        // removing a vector that was never added destroys positive
        // definiteness and must be reported, not silently corrupted
        let a = Mat::scaled_identity(4, 0.01);
        let mut ch = Cholesky::factor(&a).unwrap();
        let mut work = vec![0.0; 4];
        assert!(!ch.rank1_downdate(&[1.0, 0.0, 0.0, 0.0], &mut work));
    }

    #[test]
    fn scale_matches_scaled_refactor() {
        prop::for_cases(20, 16, |rng, _| {
            let d = 2 + rng.below(10);
            let mut a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let mut ch = Cholesky::factor(&a).unwrap();
            let f = 0.05 + rng.f64() * 2.0;
            ch.scale(f);
            a.scale(f);
            let exact = Cholesky::factor(&a).unwrap();
            assert!(ch.max_abs_diff(&exact) < 1e-12 * (1.0 + f));
        });
    }

    #[test]
    fn solve_into_matches_solve() {
        prop::for_cases(20, 17, |rng, _| {
            let d = 2 + rng.below(10);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let b = prop::vec_f64(rng, d, 2.0);
            let ch = Cholesky::factor(&a).unwrap();
            let x1 = ch.solve(&b);
            let mut x2 = vec![0.0; d];
            let mut y = vec![0.0; d];
            ch.solve_into(&b, &mut x2, &mut y);
            assert_eq!(x1, x2, "wrapper must be bit-identical");
        });
    }

    #[test]
    fn inverse_into_matches_inverse() {
        prop::for_cases(15, 18, |rng, _| {
            let d = 2 + rng.below(10);
            let a = Mat::from_rows(d, prop::spd(rng, d, 1.0));
            let ch = Cholesky::factor(&a).unwrap();
            let i1 = ch.inverse();
            let mut i2 = Mat::zeros(d);
            let mut y = vec![0.0; d];
            let mut x = vec![0.0; d];
            ch.inverse_into(&mut i2, &mut y, &mut x);
            assert_eq!(i1.max_abs_diff(&i2), 0.0, "wrapper must be bit-identical");
        });
    }
}
