//! Typed client SDK for the v2 wire protocol.
//!
//! [`ParetoClient`] speaks protocol v2 (see `server::proto` and the README
//! protocol reference) over one TCP connection: typed methods for every
//! verb, structured [`ApiError`]s carrying the server's machine-readable
//! error code, and the batch verbs (`route_batch` / `feedback_batch`)
//! that amortize socket round-trips and JSON parsing — one line in, one
//! line out, per-item results in request order.
//!
//! v1 fallback: against a pre-v2 server (responses without a `"v"`
//! field), the single-verb methods work unchanged and the batch methods
//! transparently degrade to per-item calls, so tooling built on this SDK
//! runs against either server generation.  Name-based model addressing
//! ([`ModelRef::Name`]) is v2-only.
//!
//! ```no_run
//! use paretobandit::client::ParetoClient;
//! let mut c = ParetoClient::connect("127.0.0.1:7878").unwrap();
//! let routed = c.route(1, "what is the capital of peru").unwrap();
//! c.feedback(1, 0.9, 2e-4).unwrap();
//! println!("served by {} on shard {}", routed.model, routed.shard);
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::router::ModelRef;
use crate::server::proto::{ErrorCode, PROTO_V};
use crate::util::json::Json;

/// A structured server-side error: the machine-readable code, the human
/// message and the echoed request id (when the server could parse one).
#[derive(Clone, Debug)]
pub struct ApiError {
    pub code: ErrorCode,
    pub msg: String,
    pub id: Option<u64>,
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} [{}]", self.msg, self.code.as_str())
    }
}

impl std::error::Error for ApiError {}

/// SDK error: either the transport failed (socket, malformed response) or
/// the server answered with a typed protocol error.
#[derive(Debug)]
pub enum ClientError {
    Transport(String),
    Api(ApiError),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Transport(m) => write!(f, "transport: {m}"),
            ClientError::Api(e) => write!(f, "api: {e}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> ClientError {
        ClientError::Transport(e.to_string())
    }
}

pub type ClientResult<T> = Result<T, ClientError>;

/// One successful routing decision.
#[derive(Clone, Debug)]
pub struct Routed {
    pub id: u64,
    pub arm: usize,
    pub model: String,
    pub lambda: f64,
    pub forced: bool,
    pub shard: usize,
}

/// `sync` acknowledgement.
#[derive(Clone, Copy, Debug)]
pub struct SyncInfo {
    pub synced_shards: usize,
    pub merges: u64,
}

/// Typed line-JSON client for the ParetoBandit serving protocol.
pub struct ParetoClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// set once a batch verb discovers a pre-v2 server; batch methods
    /// then degrade to per-item calls
    v1_fallback: bool,
}

impl ParetoClient {
    /// Connect to a server (`"127.0.0.1:7878"`, a `SocketAddr`, ...).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> ClientResult<ParetoClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?; // line-RPC: kill Nagle
        Ok(ParetoClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            v1_fallback: false,
        })
    }

    /// Send one raw request object and return the raw response object
    /// (escape hatch; the typed methods are built on this).
    pub fn call_raw(&mut self, req: &Json) -> ClientResult<Json> {
        writeln!(self.writer, "{}", req.to_string())?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            return Err(ClientError::Transport("server closed the connection".into()));
        }
        Json::parse(&line).map_err(|e| ClientError::Transport(format!("response parse: {e}")))
    }

    fn api_error(resp: &Json) -> ApiError {
        ApiError {
            code: resp
                .get("code")
                .and_then(Json::as_str)
                .and_then(ErrorCode::from_wire)
                .unwrap_or(ErrorCode::BadRequest),
            msg: resp
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unknown error")
                .to_string(),
            id: resp.get("id").and_then(Json::as_f64).map(|v| v as u64),
        }
    }

    fn expect_ok(resp: Json) -> ClientResult<Json> {
        if resp.get("ok").and_then(Json::as_bool) == Some(true) {
            Ok(resp)
        } else {
            Err(ClientError::Api(Self::api_error(&resp)))
        }
    }

    fn parse_routed(r: &Json) -> Option<Routed> {
        Some(Routed {
            id: r.get("id")?.as_f64()? as u64,
            arm: r.get("arm")?.as_f64()? as usize,
            model: r.get("model")?.as_str()?.to_string(),
            lambda: r.get("lambda")?.as_f64()?,
            forced: r.get("forced")?.as_bool()?,
            // pre-shard-engine servers did not report a shard
            shard: r.get("shard").and_then(Json::as_f64).unwrap_or(0.0) as usize,
        })
    }

    fn versioned(mut fields: Vec<(&str, Json)>) -> Json {
        let mut all = vec![("v", Json::Num(PROTO_V as f64))];
        all.append(&mut fields);
        Json::obj(all)
    }

    // ------------------------------------------------------------------
    // request path

    /// Route one prompt.
    pub fn route(&mut self, id: u64, prompt: &str) -> ClientResult<Routed> {
        match self.route_item(id, prompt)? {
            Ok(r) => Ok(r),
            Err(e) => Err(ClientError::Api(e)),
        }
    }

    /// transport-vs-api split used by both the single path and the v1
    /// batch fallback (an item failure must not abort a whole batch)
    fn route_item(&mut self, id: u64, prompt: &str) -> ClientResult<Result<Routed, ApiError>> {
        let resp = self.call_raw(&Self::versioned(vec![
            ("op", Json::Str("route".into())),
            ("id", Json::Num(id as f64)),
            ("prompt", Json::Str(prompt.to_string())),
        ]))?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            return Ok(Err(Self::api_error(&resp)));
        }
        Self::parse_routed(&resp)
            .map(Ok)
            .ok_or_else(|| ClientError::Transport("malformed route response".into()))
    }

    /// Report reward + realised cost for a routed id; returns the arm
    /// that served it.
    pub fn feedback(&mut self, id: u64, reward: f64, cost: f64) -> ClientResult<usize> {
        match self.feedback_item(id, reward, cost)? {
            Ok(arm) => Ok(arm),
            Err(e) => Err(ClientError::Api(e)),
        }
    }

    fn feedback_item(
        &mut self,
        id: u64,
        reward: f64,
        cost: f64,
    ) -> ClientResult<Result<usize, ApiError>> {
        let resp = self.call_raw(&Self::versioned(vec![
            ("op", Json::Str("feedback".into())),
            ("id", Json::Num(id as f64)),
            ("reward", Json::Num(reward)),
            ("cost", Json::Num(cost)),
        ]))?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            return Ok(Err(Self::api_error(&resp)));
        }
        Ok(Ok(resp.get("arm").and_then(Json::as_f64).unwrap_or(0.0) as usize))
    }

    /// Route a batch of `(id, prompt)` items in ONE socket round-trip;
    /// per-item results come back in request order.  Against a pre-v2
    /// server this transparently degrades to per-item calls.
    pub fn route_batch<S: AsRef<str>>(
        &mut self,
        items: &[(u64, S)],
    ) -> ClientResult<Vec<Result<Routed, ApiError>>> {
        if self.v1_fallback {
            return items
                .iter()
                .map(|(id, p)| self.route_item(*id, p.as_ref()))
                .collect();
        }
        let req = Self::versioned(vec![
            ("op", Json::Str("route_batch".into())),
            (
                "items",
                Json::Arr(
                    items
                        .iter()
                        .map(|(id, p)| {
                            Json::obj(vec![
                                ("id", Json::Num(*id as f64)),
                                ("prompt", Json::Str(p.as_ref().to_string())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let resp = self.call_raw(&req)?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            // a pre-v2 server answers without a "v" stamp and does not
            // know the batch verbs: fall back to per-item calls
            if resp.get("v").is_none() {
                self.v1_fallback = true;
                return items
                    .iter()
                    .map(|(id, p)| self.route_item(*id, p.as_ref()))
                    .collect();
            }
            return Err(ClientError::Api(Self::api_error(&resp)));
        }
        let results = resp
            .get("results")
            .and_then(Json::as_arr)
            .ok_or_else(|| ClientError::Transport("malformed route_batch response".into()))?;
        if results.len() != items.len() {
            return Err(ClientError::Transport(format!(
                "route_batch: {} results for {} items",
                results.len(),
                items.len()
            )));
        }
        results
            .iter()
            .map(|r| {
                if r.get("ok").and_then(Json::as_bool) == Some(true) {
                    Self::parse_routed(r)
                        .map(Ok)
                        .ok_or_else(|| ClientError::Transport("malformed batch item".into()))
                } else {
                    Ok(Err(Self::api_error(r)))
                }
            })
            .collect()
    }

    /// Report a batch of `(id, reward, cost)` observations in ONE socket
    /// round-trip; per-item acks (the serving arm) in request order.
    pub fn feedback_batch(
        &mut self,
        items: &[(u64, f64, f64)],
    ) -> ClientResult<Vec<Result<usize, ApiError>>> {
        if self.v1_fallback {
            return items
                .iter()
                .map(|&(id, r, c)| self.feedback_item(id, r, c))
                .collect();
        }
        let req = Self::versioned(vec![
            ("op", Json::Str("feedback_batch".into())),
            (
                "items",
                Json::Arr(
                    items
                        .iter()
                        .map(|&(id, reward, cost)| {
                            Json::obj(vec![
                                ("id", Json::Num(id as f64)),
                                ("reward", Json::Num(reward)),
                                ("cost", Json::Num(cost)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let resp = self.call_raw(&req)?;
        if resp.get("ok").and_then(Json::as_bool) != Some(true) {
            if resp.get("v").is_none() {
                self.v1_fallback = true;
                return items
                    .iter()
                    .map(|&(id, r, c)| self.feedback_item(id, r, c))
                    .collect();
            }
            return Err(ClientError::Api(Self::api_error(&resp)));
        }
        let results = resp
            .get("results")
            .and_then(Json::as_arr)
            .ok_or_else(|| ClientError::Transport("malformed feedback_batch response".into()))?;
        if results.len() != items.len() {
            return Err(ClientError::Transport(format!(
                "feedback_batch: {} results for {} items",
                results.len(),
                items.len()
            )));
        }
        Ok(results
            .iter()
            .map(|r| {
                if r.get("ok").and_then(Json::as_bool) == Some(true) {
                    Ok(r.get("arm").and_then(Json::as_f64).unwrap_or(0.0) as usize)
                } else {
                    Err(Self::api_error(r))
                }
            })
            .collect())
    }

    // ------------------------------------------------------------------
    // admin path

    /// Register a model; `prior` is an optional `(n_eff, r0)` heuristic
    /// prior.  Returns the stable arm id.  Duplicate active names are
    /// rejected with [`ErrorCode::DuplicateModel`].
    pub fn add_model(
        &mut self,
        name: &str,
        price_in: f64,
        price_out: f64,
        prior: Option<(f64, f64)>,
    ) -> ClientResult<usize> {
        let mut fields = vec![
            ("op", Json::Str("add_model".into())),
            ("name", Json::Str(name.to_string())),
            ("price_in", Json::Num(price_in)),
            ("price_out", Json::Num(price_out)),
        ];
        if let Some((n_eff, r0)) = prior {
            fields.push(("n_eff", Json::Num(n_eff)));
            fields.push(("r0", Json::Num(r0)));
        }
        let resp = Self::expect_ok(self.call_raw(&Self::versioned(fields))?)?;
        resp.get("arm")
            .and_then(Json::as_f64)
            .map(|a| a as usize)
            .ok_or_else(|| ClientError::Transport("malformed add_model response".into()))
    }

    /// Retire a model by arm id or name; returns the retired slot.
    /// (Name addressing is v2-only.)
    pub fn delete_model(&mut self, model: &ModelRef) -> ClientResult<usize> {
        let mut fields = vec![("op", Json::Str("delete_model".into()))];
        push_model_ref(&mut fields, model);
        let resp = Self::expect_ok(self.call_raw(&Self::versioned(fields))?)?;
        Ok(arm_or_ref(&resp, model))
    }

    /// Push new list prices by arm id or name; returns the slot hit.
    pub fn reprice(
        &mut self,
        model: &ModelRef,
        price_in: f64,
        price_out: f64,
    ) -> ClientResult<usize> {
        let mut fields = vec![
            ("op", Json::Str("reprice".into())),
            ("price_in", Json::Num(price_in)),
            ("price_out", Json::Num(price_out)),
        ];
        push_model_ref(&mut fields, model);
        let resp = Self::expect_ok(self.call_raw(&Self::versioned(fields))?)?;
        Ok(arm_or_ref(&resp, model))
    }

    /// Inject one scenario event (`set_price` / `add_model` /
    /// `remove_model` / `set_budget` / `snapshot` / `restart`) — the
    /// generic admin verb the scenario engine's wire host drives live
    /// drift with.  Environment-side events are rejected by the server
    /// with `bad_request`.  Returns the raw response object, whose
    /// fields are those of the mapped admin op.
    pub fn inject(&mut self, event: &crate::scenario::Event) -> ClientResult<Json> {
        Self::expect_ok(self.call_raw(&Self::versioned(vec![
            ("op", Json::Str("inject".into())),
            ("event", event.to_json()),
        ]))?)
    }

    /// Offer a model to the deployment layer's candidate pool (the
    /// `offer_model` verb).  The deployment policy — not the caller —
    /// decides if and when the candidate occupies one of the K serving
    /// slots.  `quality` is an optional prior quality hint in `[0, 1]`.
    /// Returns `(pooled, deployed)` occupancy after the offer.  Servers
    /// running without `--deploy` reject the verb with `bad_request`.
    pub fn offer_model(
        &mut self,
        name: &str,
        price_in: f64,
        price_out: f64,
        quality: Option<f64>,
    ) -> ClientResult<(usize, usize)> {
        let mut fields = vec![
            ("op", Json::Str("offer_model".into())),
            ("name", Json::Str(name.to_string())),
            ("price_in", Json::Num(price_in)),
            ("price_out", Json::Num(price_out)),
        ];
        if let Some(q) = quality {
            fields.push(("quality", Json::Num(q)));
        }
        let resp = Self::expect_ok(self.call_raw(&Self::versioned(fields))?)?;
        Ok((
            resp.get("pooled").and_then(Json::as_f64).unwrap_or(0.0) as usize,
            resp.get("deployed").and_then(Json::as_f64).unwrap_or(0.0) as usize,
        ))
    }

    /// Deployment-layer status (the `deploy_status` verb) as raw JSON:
    /// policy name, slot cap, candidate pool, per-slot incumbents with
    /// measured reward/cost, and the offer/deploy/evict counters.
    /// Servers running without `--deploy` reject the verb with
    /// `bad_request`.
    pub fn deploy_status(&mut self) -> ClientResult<Json> {
        Self::expect_ok(
            self.call_raw(&Self::versioned(vec![(
                "op",
                Json::Str("deploy_status".into()),
            )]))?,
        )
    }

    /// Persist the server's learned router state to a **server-side**
    /// file (on the sharded engine: the post-merge global posterior).
    /// Returns `(active arms, router step)`.
    pub fn snapshot(&mut self, path: &str) -> ClientResult<(usize, u64)> {
        let resp = Self::expect_ok(self.call_raw(&Self::versioned(vec![
            ("op", Json::Str("snapshot".into())),
            ("path", Json::Str(path.to_string())),
        ]))?)?;
        Ok((
            resp.get("arms").and_then(Json::as_f64).unwrap_or(0.0) as usize,
            resp.get("t").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        ))
    }

    /// Warm-restart the server (every shard of an engine) from a
    /// server-side snapshot file.  Returns `(active arms, router step)`.
    pub fn restore(&mut self, path: &str) -> ClientResult<(usize, u64)> {
        let resp = Self::expect_ok(self.call_raw(&Self::versioned(vec![
            ("op", Json::Str("restore".into())),
            ("path", Json::Str(path.to_string())),
        ]))?)?;
        Ok((
            resp.get("arms").and_then(Json::as_f64).unwrap_or(0.0) as usize,
            resp.get("t").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        ))
    }

    /// Change the $/request ceiling at runtime; echoes the new budget.
    pub fn set_budget(&mut self, budget: f64) -> ClientResult<f64> {
        let resp = Self::expect_ok(self.call_raw(&Self::versioned(vec![
            ("op", Json::Str("set_budget".into())),
            ("budget", Json::Num(budget)),
        ]))?)?;
        Ok(resp.get("budget").and_then(Json::as_f64).unwrap_or(budget))
    }

    /// Serving-metrics snapshot as raw JSON: counters, latency
    /// percentiles, per-shard and per-arm splits, plus the active policy
    /// name (`"policy"`), the pacer dual at the last routed request
    /// (`"lambda"`) and the per-shadow counterfactual series
    /// (`"shadows"`, see [`ParetoClient::compare`]).
    pub fn metrics(&mut self) -> ClientResult<Json> {
        let resp = self.call_raw(&Self::versioned(vec![("op", Json::Str("metrics".into()))]))?;
        // pre-v2 servers returned the bare snapshot with neither "ok"
        // nor "v"; that shape is a success, not an error
        if resp.get("ok").is_none() && resp.get("v").is_none() {
            return Ok(resp);
        }
        Self::expect_ok(resp)
    }

    /// Served-vs-shadow policy comparison (the `compare` verb): the
    /// active policy's summary (`"served"`) plus every shadow policy's
    /// counterfactual quality/cost/λ series (`"shadows"`), as raw JSON.
    /// Requires a v2 server; shadowless servers answer with an empty
    /// `shadows` array.
    pub fn compare(&mut self) -> ClientResult<Json> {
        Self::expect_ok(
            self.call_raw(&Self::versioned(vec![("op", Json::Str("compare".into()))]))?,
        )
    }

    /// Force a merge/broadcast cycle (engine) or a well-defined no-op
    /// (single-worker server, which answers as a 1-shard engine).
    pub fn sync(&mut self) -> ClientResult<SyncInfo> {
        let resp = Self::expect_ok(
            self.call_raw(&Self::versioned(vec![("op", Json::Str("sync".into()))]))?,
        )?;
        Ok(SyncInfo {
            synced_shards: resp
                .get("synced_shards")
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as usize,
            merges: resp.get("merges").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        })
    }

    /// Ask the server to shut down.
    pub fn shutdown(&mut self) -> ClientResult<()> {
        Self::expect_ok(
            self.call_raw(&Self::versioned(vec![("op", Json::Str("shutdown".into()))]))?,
        )?;
        Ok(())
    }
}

fn push_model_ref(fields: &mut Vec<(&str, Json)>, model: &ModelRef) {
    match model {
        ModelRef::Arm(a) => fields.push(("arm", Json::Num(*a as f64))),
        ModelRef::Name(n) => fields.push(("model", Json::Str(n.clone()))),
    }
}

/// The resolved slot from a v2 response; a v1 server omits it, in which
/// case an arm-addressed request already knows its slot.
fn arm_or_ref(resp: &Json, model: &ModelRef) -> usize {
    resp.get("arm")
        .and_then(Json::as_f64)
        .map(|a| a as usize)
        .unwrap_or(match model {
            ModelRef::Arm(a) => *a,
            ModelRef::Name(_) => 0,
        })
}
