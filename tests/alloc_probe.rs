//! Zero-allocation guarantee for the routing hot path.
//!
//! A counting global allocator wraps `System`; after warmup (buffers sized,
//! posteriors populated) the steady-state paths must not touch the heap:
//!
//!   * `ParetoRouter::route`            — scoring reuses score_buf/id_buf
//!   * `ParetoRouter::feedback`         — rank-1 factor/inverse maintenance
//!     plus the periodic exact refresh (REFRESH_EVERY falls inside the
//!     measured window, so the refresh itself is asserted alloc-free too)
//!   * `PolicyHost::route_batch_into`   — batched decisions into a reused
//!     output buffer
//!   * `LogWriter::append_decision` / `append_feedback` — decision-log
//!     capture frames staged in the reused scratch buffer and written
//!     through the fixed-size `BufWriter` (rotation is the only
//!     allocating step and stays outside the measured window)
//!
//! This file is its own integration binary (one test) because the
//! `#[global_allocator]` is process-wide: concurrent tests in a shared
//! binary would pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use paretobandit::log::{CaptureMeta, LogWriter, DEFAULT_SEGMENT_BYTES};
use paretobandit::router::{ParetoRouter, PolicyHost, Prior, RouteDecision, RouterConfig};
use paretobandit::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

const D: usize = 26;

fn ctx(rng: &mut Rng) -> Vec<f64> {
    let mut x: Vec<f64> = (0..D).map(|_| rng.normal()).collect();
    x[D - 1] = 1.0;
    x
}

fn three_model_router(seed: u64) -> ParetoRouter {
    let mut r = ParetoRouter::new(RouterConfig::paretobandit(D, 6.6e-4, seed));
    r.add_model("llama", 0.10, 0.10, Prior::Cold);
    r.add_model("mistral", 0.40, 1.60, Prior::Cold);
    r.add_model("gemini", 1.25, 10.0, Prior::Cold);
    r
}

#[test]
fn hot_path_does_not_allocate_after_warmup() {
    let mut rng = Rng::new(2);
    let xs: Vec<Vec<f64>> = (0..512).map(|_| ctx(&mut rng)).collect();
    let rewards: Vec<f64> = (0..512).map(|_| 0.5 + 0.4 * rng.f64()).collect();

    // --- standalone router ------------------------------------------------
    let mut r = three_model_router(1);
    // warm well past REFRESH_EVERY so the periodic exact refresh (the
    // alloc-free refactor/inverse_into path) fires inside the measured
    // windows below rather than only during warmup
    for i in 0..2_000 {
        let x = &xs[i % xs.len()];
        let d = r.route(x);
        r.feedback(d.arm, x, rewards[i % rewards.len()], 2.0e-4);
    }

    let before = allocs();
    for (i, x) in xs.iter().cycle().take(1_000).enumerate() {
        let d = r.route(x);
        std::hint::black_box((i, d.arm));
    }
    assert_eq!(allocs() - before, 0, "route() allocated in steady state");

    let before = allocs();
    for i in 0..1_000 {
        let x = &xs[i % xs.len()];
        let d = r.route(x);
        r.feedback(d.arm, x, rewards[i % rewards.len()], 2.0e-4);
    }
    assert_eq!(
        allocs() - before,
        0,
        "route()+feedback() allocated in steady state (refresh cadence included)"
    );

    // --- hot path after registry churn --------------------------------------
    // 40 add/delete cycles leave the registry with a long tombstone
    // history; the active-index eligibility scan must keep route() and
    // feedback() off the heap regardless (a naive full-slot walk stays
    // alloc-free too, but the index is also what keeps this O(active) —
    // see benches/routing_hot.rs)
    for c in 0..40 {
        let slot = r.add_model(&format!("churn-{c}"), 0.2, 0.9, Prior::Cold);
        for i in 0..8 {
            let x = &xs[(c * 8 + i) % xs.len()];
            let d = r.route(x);
            r.feedback(d.arm, x, rewards[i % rewards.len()], 2.0e-4);
        }
        r.delete_model(slot);
    }
    // one settling pass re-sizes any buffer the portfolio peak stretched
    for i in 0..200 {
        let x = &xs[i % xs.len()];
        let d = r.route(x);
        r.feedback(d.arm, x, rewards[i % rewards.len()], 2.0e-4);
    }
    let before = allocs();
    for i in 0..1_000 {
        let x = &xs[i % xs.len()];
        let d = r.route(x);
        r.feedback(d.arm, x, rewards[i % rewards.len()], 2.0e-4);
    }
    assert_eq!(
        allocs() - before,
        0,
        "route()+feedback() allocated after add/delete churn"
    );

    // --- hosted batched path ----------------------------------------------
    let mut host = PolicyHost::new(Box::new(three_model_router(3)), None);
    for i in 0..1_500 {
        let x = &xs[i % xs.len()];
        let d = host.route(x);
        host.feedback(d.arm, x, rewards[i % rewards.len()], 2.0e-4);
    }
    let batch: Vec<Vec<f64>> = xs[..64].to_vec();
    let mut out: Vec<RouteDecision> = Vec::with_capacity(batch.len());
    // two priming calls size every internal buffer (pick_buf, eligibility
    // mirror) before the measured window
    host.route_batch_into(&batch, &mut out);
    host.route_batch_into(&batch, &mut out);

    let before = allocs();
    for _ in 0..200 {
        host.route_batch_into(&batch, &mut out);
        std::hint::black_box(out.len());
    }
    assert_eq!(
        allocs() - before,
        0,
        "route_batch_into() allocated in steady state"
    );

    // --- decision-log append path -----------------------------------------
    let dir = std::env::temp_dir().join(format!("pb_alloc_log_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let meta = CaptureMeta {
        shard: 0,
        d: D as u32,
        seed: 1,
        budget: Some(6.6e-4),
        policy: "paretobandit".to_string(),
        warm: false,
        models: Vec::new(),
    };
    let mut w = LogWriter::create(&dir, meta, DEFAULT_SEGMENT_BYTES).expect("log writer");
    let x = &xs[0];
    let eligible = [0usize, 1, 2];
    let blended = [0.1, 0.9, 5.6];
    let c_tilde = [0.09, 0.85, 5.0];
    // warm the scratch buffer past the largest frame this stream stages
    for i in 0..64u64 {
        w.append_decision(i, i, 0.4, 1, false, 3, x, &eligible, &blended, &c_tilde)
            .unwrap();
        w.append_feedback(i, 1, 0.7, 2.0e-4, true).unwrap();
    }
    let before = allocs();
    for i in 0..1_000u64 {
        w.append_decision(i, i, 0.4, 1, false, 3, x, &eligible, &blended, &c_tilde)
            .unwrap();
        w.append_feedback(i, 1, 0.7, 2.0e-4, true).unwrap();
    }
    assert_eq!(
        allocs() - before,
        0,
        "log append allocated in steady state"
    );
    drop(w);
    let _ = std::fs::remove_dir_all(&dir);
}
