//! Sharded-engine acceptance: K shards fed round-robin from one client
//! must (a) converge to the same arm ranking as the 1-shard baseline on
//! stationary traffic — shards only see 1/K of the stream, so this only
//! holds because the merge/broadcast cycle shares posteriors — and (b)
//! hold the *global* mean per-request cost within the paper's 0.4%
//! overshoot tolerance of the budget ceiling, which only holds because the
//! dollar ledger is shared rather than per-replica.
//!
//! Override the traffic volume with PB_CONV_REQS (same env-override
//! pattern as CRITERION_MEASUREMENT_TIME) when running on slow hardware.

use std::sync::Arc;
use std::time::Duration;

use paretobandit::client::ParetoClient;
use paretobandit::pacer::{PacerConfig, SharedPacer};
use paretobandit::router::{ContextCache, ParetoRouter, Prior, RouterConfig};
use paretobandit::server::{EngineConfig, Metrics, ServerState, ShardedEngine};
use paretobandit::sim::hash_features;
use paretobandit::util::env_or;
use paretobandit::util::rng::Rng;

const D: usize = 8;
const BUDGET: f64 = 4e-4;
/// realised $/request per arm (mistral is the value pick at 2x budget, so
/// the pacer must mix it with llama; gemini is 6x budget)
const COSTS: [f64; 3] = [1e-4, 8e-4, 2.4e-3];
/// gemini's quality plateaus below mistral's: paying 6x the budget buys
/// nothing, so a correct router must rank it last
const QUALITY: [f64; 3] = [0.55, 0.90, 0.80];
/// force a merge cycle this often (timer merges are disabled so runs are
/// deterministic)
const SYNC_EVERY: u64 = 500;

fn spawn_engine(workers: usize) -> ShardedEngine {
    let ledger = Arc::new(SharedPacer::new(PacerConfig::new(BUDGET)));
    let build = move |shard: usize| {
        // tabula-rasa hyperparameters: cold-start exploration must work
        // without warmup priors (α=0.05 keeps the confidence bonus on the
        // reward scale — the paper's no-prior knee point)
        let mut router =
            ParetoRouter::new(RouterConfig::tabula_rasa(D, Some(BUDGET), 1000 + shard as u64));
        router.use_shared_pacer(ledger.clone());
        router.add_model("llama", 0.10, 0.10, Prior::Cold);
        router.add_model("mistral", 0.40, 1.60, Prior::Cold);
        router.add_model("gemini", 1.00, 3.00, Prior::Cold);
        ServerState::new(
            router,
            ContextCache::new(65536),
            Box::new(|t: &str| Ok(hash_features(t, D))),
            Arc::new(Metrics::new()),
        )
    };
    let cfg = EngineConfig::new(workers).merge_every(Duration::from_secs(3600));
    ShardedEngine::spawn("127.0.0.1:0", cfg, build).unwrap()
}

struct RunResult {
    counts: [u64; 3],
    /// mean $/request over the post-warmup window
    mean_cost_post: f64,
}

/// Drive `reqs` stationary requests through an engine; rewards depend only
/// on the arm (plus noise), costs are fixed per arm.
fn drive(workers: usize, reqs: u64) -> RunResult {
    let engine = spawn_engine(workers);
    let mut client = ParetoClient::connect(engine.addr).unwrap();
    let mut rng = Rng::new(7);
    let warmup = reqs / 3;
    let mut counts = [0u64; 3];
    let mut post_spend = 0.0;
    let mut post_n = 0u64;
    for i in 0..reqs {
        let routed = client
            .route(i, &format!("stationary prompt {} tail {}", i % 97, i % 13))
            .unwrap();
        let arm = routed.arm;
        assert!(arm < 3);
        counts[arm] += 1;
        let cost = COSTS[arm];
        let reward = (QUALITY[arm] + rng.normal() * 0.03).clamp(0.0, 1.0);
        if i >= warmup {
            post_spend += cost;
            post_n += 1;
        }
        client.feedback(i, reward, cost).unwrap();
        if (i + 1) % SYNC_EVERY == 0 {
            let s = client.sync().unwrap();
            assert_eq!(s.synced_shards, workers);
        }
    }
    // final cycle so every shard ends on the merged global posterior
    client.sync().unwrap();
    let m = client.metrics().unwrap();
    assert_eq!(m.get("requests").unwrap().as_f64(), Some(reqs as f64));
    assert_eq!(m.get("workers").unwrap().as_f64(), Some(workers as f64));
    // round-robin dispatch splits routes across shards exactly evenly
    let per_shard = m.get("per_shard").unwrap().as_arr().unwrap();
    assert_eq!(per_shard.len(), workers);
    for s in per_shard {
        let n = s.as_f64().unwrap();
        assert!(
            (n - reqs as f64 / workers as f64).abs() <= 1.0,
            "unbalanced shard load: {n} of {reqs}"
        );
    }
    engine.stop();
    RunResult {
        counts,
        mean_cost_post: post_spend / post_n as f64,
    }
}

fn ranking(counts: &[u64; 3]) -> [usize; 3] {
    let mut order = [0usize, 1, 2];
    order.sort_by_key(|&a| std::cmp::Reverse(counts[a]));
    order
}

#[test]
fn four_shards_match_single_shard_ranking_and_hold_the_global_budget() {
    let reqs: u64 = env_or("PB_CONV_REQS", 21_000);
    let single = drive(1, reqs);
    let sharded = drive(4, reqs);

    // (a) same final arm ranking as the 1-shard baseline
    let r1 = ranking(&single.counts);
    let r4 = ranking(&sharded.counts);
    assert_eq!(
        r1, r4,
        "rankings diverge: 1-shard {:?} vs 4-shard {:?}",
        single.counts, sharded.counts
    );
    // the 6x-over-budget arm must end up last in both
    assert_eq!(r1[2], 2, "gemini should be rank 3: {:?}", single.counts);
    // the ranking is meaningful: top two arms are clearly separated
    for r in [&single, &sharded] {
        let top = r.counts[r4[0]] as f64;
        let second = r.counts[r4[1]] as f64;
        assert!(
            top > second * 1.1,
            "degenerate ranking, counts too close: {:?}",
            r.counts
        );
    }

    // (b) global mean $/request within the paper's 0.4% overshoot
    // tolerance of the ceiling, post-warmup — for BOTH configurations;
    // for the sharded one this exercises the shared atomic ledger
    for (label, r) in [("1-shard", &single), ("4-shard", &sharded)] {
        assert!(
            r.mean_cost_post <= BUDGET * 1.004,
            "{label}: mean ${:.6e}/req exceeds ceiling ${BUDGET:.1e} by >0.4%",
            r.mean_cost_post
        );
        assert!(
            r.mean_cost_post >= BUDGET * 0.5,
            "{label}: budget underused (${:.6e}/req) — pacer stuck on the cheap arm?",
            r.mean_cost_post
        );
    }
}
