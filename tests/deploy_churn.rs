//! Deployment-layer churn stress (docs/deployment.md).
//!
//! Three fronts:
//!
//! * **Scenario host** — a 600-event offer/expire stream (≥500 churn
//!   cycles) runs to completion under every registered deployment
//!   policy, with no stale-arm errors and an O(active) snapshot: the
//!   tombstone history of hundreds of retired slots must collapse to
//!   RLE markers, not grow the state file per-candidate.
//! * **SlotManager** — a mid-stream snapshot restores bit-identically:
//!   the restored manager and the donor make the same decisions on the
//!   same continued stream, byte-for-byte in `export_state`.
//! * **Wire host** — the ISSUE acceptance shape: a 4-shard engine under
//!   `--deploy ucb --slots 3` digests a 280-candidate stream (540 churn
//!   verbs over TCP), never exceeds K deployed, keeps the pool bounded,
//!   and snapshot → restore carries the deployment layer so the revived
//!   engine reports identical deployment state and routes like the
//!   donor.

use std::sync::Arc;
use std::time::Duration;

use paretobandit::client::ParetoClient;
use paretobandit::deploy::{build_deploy, deploy_names, DeployAction, SlotManager};
use paretobandit::exp::ExpEnv;
use paretobandit::pacer::{PacerConfig, SharedPacer};
use paretobandit::router::{
    build_policy, BuildCtx, ContextCache, ModelSpec, ParetoRouter, Prior, RouterConfig, SlotStat,
};
use paretobandit::scenario::{run_scenario, snapshot, Event, RunOptions, ScenarioSpec};
use paretobandit::server::{EngineConfig, Metrics, ServerState, ShardedEngine};
use paretobandit::sim::{hash_features, FlashScenario};
use paretobandit::util::json::Json;

// ---------------------------------------------------------------- scenario --

fn churn_spec(deploy: &str) -> ScenarioSpec {
    ScenarioSpec::from_toml(&format!(
        "[scenario]\n\
         name = \"churn\"\n\
         steps = 700\n\
         k = 3\n\
         budget = 6.6e-4\n\
         stream_seed = 9300\n\
         deploy = \"{deploy}\"\n\
         slots = 3\n\
         \n\
         [[event]]\n\
         at = 1\n\
         op = \"stream_inventory\"\n\
         count = 300\n\
         every = 2\n\
         expire_after = 40\n\
         seed = 77\n"
    ))
    .expect("churn spec parses")
}

#[test]
fn five_hundred_churn_cycles_run_clean_under_every_deploy_policy() {
    let env = ExpEnv::load(FlashScenario::GoodCheap);
    assert_eq!(deploy_names(), vec!["fifo", "greedy", "ucb"]);
    for dspec in ["fifo", "greedy:8", "ucb:16"] {
        let spec = churn_spec(dspec);
        let models: Vec<ModelSpec> = (0..spec.k)
            .map(|m| {
                let ws = &env.world.models[m];
                ModelSpec::new(ws.name, ws.price_in_per_m, ws.price_out_per_m)
            })
            .collect();
        let mut host = build_policy(
            "paretobandit",
            &BuildCtx {
                d: env.d(),
                budget: spec.budget,
                seed: 7,
                models: &models,
            },
        )
        .expect("routing policy builds");
        let run = run_scenario(
            &spec,
            &env,
            &env.world,
            &mut host,
            &RunOptions {
                seed: 7,
                reprice_router: true,
            },
        )
        .unwrap_or_else(|e| panic!("{dspec}: scenario failed under churn: {e}"));
        // every step routed and judged — no stale-arm decision survived
        assert_eq!(run.flat().len(), 700, "{dspec}");
        let offers = run.event_log.iter().filter(|l| l.contains("offer_model")).count();
        let expires = run.event_log.iter().filter(|l| l.contains("expire_model")).count();
        assert!(
            offers + expires >= 500,
            "{dspec}: only {offers} offers + {expires} expires applied"
        );
        // snapshot compactness: hundreds of retired slots must collapse
        // to RLE markers — the state stays O(active), not O(offered)
        let st = host.export_state();
        let slots = st
            .get("slots")
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("{dspec}: state has no slots array"));
        assert!(
            slots.len() <= 40,
            "{dspec}: snapshot slot array holds {} entries after {} deploys — \
             tombstones are not run-length encoded",
            slots.len(),
            offers
        );
        let bytes = st.to_string().len();
        assert!(
            bytes < 400_000,
            "{dspec}: snapshot grew to {bytes} bytes under churn"
        );
    }
}

// ------------------------------------------------------------- slot manager --

/// Deterministic per-slot cumulative stats at churn cycle `i` (pure
/// function, so a restored manager can be fed the identical stream).
fn stats_at(i: u64, len: usize) -> Vec<SlotStat> {
    (0..len)
        .map(|s| {
            let r = 0.35 + 0.6 * (((s * 37) % 100) as f64) / 100.0;
            let c = 1e-4 * (1.0 + ((s * 13) % 7) as f64);
            SlotStat {
                n: i + 1,
                reward_sum: (i + 1) as f64 * r,
                cost_sum: (i + 1) as f64 * c,
            }
        })
        .collect()
}

/// One deterministic churn cycle: offer `c<i>`, expire `c<i-25>`, feed
/// stats, tick, and confirm deploys with slot id = cycle index.
fn drive(m: &mut SlotManager, i: u64) {
    let pi = 0.1 + ((i % 17) as f64) * 0.05;
    let po = 0.4 + ((i % 11) as f64) * 0.2;
    let q = 0.35 + ((i % 13) as f64) / 20.0;
    m.offer(&format!("c{i}"), pi, po, Some(q));
    if i >= 25 {
        for a in m.expire(&format!("c{}", i - 25)) {
            assert!(matches!(a, DeployAction::Evict { .. }));
        }
    }
    m.record_stats(&stats_at(i, 512));
    for a in m.tick() {
        if let DeployAction::Deploy(c) = a {
            // registry slot ids only need to be unique and identical on
            // both sides; the cycle index is both
            m.note_deployed(&c.name, i as usize);
        }
    }
}

#[test]
fn slot_manager_restores_bit_identically_mid_stream() {
    for spec in ["fifo", "greedy:4", "ucb:8"] {
        let mut donor = build_deploy(spec, 3).unwrap();
        for i in 0..250 {
            drive(&mut donor, i);
        }
        let snap = donor.export_state();
        let mut revived = build_deploy(spec, 3).unwrap();
        revived.restore_state(&snap).unwrap();
        assert_eq!(
            donor.export_state().to_string(),
            revived.export_state().to_string(),
            "{spec}: restore must reproduce the captured state byte-for-byte"
        );
        // the continued stream produces identical decisions on both sides
        for i in 250..500 {
            drive(&mut donor, i);
            drive(&mut revived, i);
            if i % 50 == 0 {
                assert_eq!(
                    donor.status().to_string(),
                    revived.status().to_string(),
                    "{spec}: diverged at cycle {i}"
                );
            }
        }
        assert_eq!(
            donor.export_state().to_string(),
            revived.export_state().to_string(),
            "{spec}: post-restore stream diverged"
        );
        // a wrong-kind snapshot is refused, not half-applied
        let mut wrong = build_deploy("fifo", 3).unwrap();
        if spec != "fifo" {
            assert!(wrong.restore_state(&snap).is_err());
            assert_eq!(wrong.occupied(), 0);
        }
    }
}

// ------------------------------------------------------------------- wire --

const D: usize = 8;
const BUDGET: f64 = 1e-3;

fn spawn_deploy_engine(
    workers: usize,
    restore_from: Option<std::path::PathBuf>,
) -> ShardedEngine {
    let ledger = Arc::new(SharedPacer::new(PacerConfig::new(BUDGET)));
    let mgr_restore = restore_from.clone();
    let build = move |shard: usize| {
        let mut router =
            ParetoRouter::new(RouterConfig::tabula_rasa(D, Some(BUDGET), 500 + shard as u64));
        router.use_shared_pacer(ledger.clone());
        match &restore_from {
            Some(path) => {
                let st = snapshot::load(path).expect("snapshot file");
                router.restore_state(&st).expect("restore");
                if shard > 0 {
                    router.fork_rng(shard as u64);
                }
            }
            None => {
                router.add_model("llama", 0.1, 0.1, Prior::Cold);
                router.add_model("mistral", 0.4, 1.6, Prior::Cold);
            }
        }
        ServerState::new(
            router,
            ContextCache::new(4096),
            Box::new(|t: &str| Ok(hash_features(t, D))),
            Arc::new(Metrics::new()),
        )
    };
    // mirror `serve --deploy ucb --slots 3 --restore SNAP`: the manager
    // is rebuilt from its spec and warm-started from the snapshot's
    // embedded deploy state before the engine spawns
    let mut mgr = build_deploy("ucb:16", 3).unwrap();
    if let Some(path) = &mgr_restore {
        let (_, st) = snapshot::load_value(path).expect("snapshot value");
        let d = st.get("deploy").expect("snapshot embeds deploy state");
        mgr.restore_state(d).expect("deploy restore");
    }
    ShardedEngine::spawn_deploy(
        "127.0.0.1:0",
        // long interval: deployment ticks come from the churn verbs, so
        // the decision sequence is deterministic, not timer-raced
        EngineConfig::new(workers).merge_every(Duration::from_secs(600)),
        Some(mgr),
        build,
    )
    .unwrap()
}

/// Route 100 eval prompts (no feedback) and count per-arm allocations.
fn allocation(c: &mut ParetoClient, id_base: u64, arms: usize) -> Vec<usize> {
    let mut counts = vec![0usize; arms];
    for i in 0..100u64 {
        let r = c.route(id_base + i, &format!("eval prompt {i}")).unwrap();
        counts[r.arm] += 1;
    }
    counts
}

#[test]
fn four_shard_engine_digests_a_280_candidate_stream_and_restores() {
    let engine = spawn_deploy_engine(4, None);
    let mut c = ParetoClient::connect(engine.addr).unwrap();
    let mut max_deployed = 0usize;
    let mut id = 0u64;
    for i in 0..280u64 {
        let pi = 0.1 + ((i % 17) as f64) * 0.05;
        let po = 0.4 + ((i % 11) as f64) * 0.2;
        let q = 0.35 + ((i % 13) as f64) / 20.0;
        let (pooled, deployed) = c
            .offer_model(&format!("cand-{i}"), pi, po, Some(q))
            .unwrap();
        assert!(deployed <= 3, "offer {i}: {deployed} deployed breaches K=3");
        assert!(pooled <= i as usize + 1, "offer {i}: pool leak ({pooled})");
        max_deployed = max_deployed.max(deployed);
        if i >= 20 {
            c.inject(&Event::ExpireModel {
                model: format!("cand-{}", i - 20),
            })
            .unwrap();
        }
        // keep routed traffic flowing through the churn; 4 routes per
        // offer keeps the round-robin ticket ≡ 0 mod 4 for the
        // allocation comparison below
        for _ in 0..4 {
            let r = c.route(id, &format!("churn traffic {id}")).unwrap();
            c.feedback(id, if r.arm == 1 { 0.9 } else { 0.4 }, 1e-4).unwrap();
            id += 1;
        }
    }
    assert_eq!(max_deployed, 3, "the stream never filled all 3 slots");
    let st = c.deploy_status().unwrap();
    assert_eq!(st.get("policy").and_then(Json::as_str), Some("ucb:16"));
    let pool = st.get("pool").and_then(Json::as_f64).unwrap();
    assert!(
        pool <= 280.0 - 260.0 + 3.0,
        "expired candidates must leave the pool (pool={pool})"
    );
    let evictions = st.get("evictions").and_then(Json::as_f64).unwrap();
    assert!(evictions >= 1.0, "280 candidates over 3 slots must evict");
    let offers = st.get("offers").and_then(Json::as_f64).unwrap();
    let expires = st.get("expires").and_then(Json::as_f64).unwrap();
    assert_eq!(offers + expires, 540.0, "540 churn verbs over the wire");

    // snapshot: bounded despite ~280 retired slots, deploy state embedded
    let dir = std::env::temp_dir().join(format!("pb_churn_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("churn.snap.json");
    c.snapshot(path.to_str().unwrap()).unwrap();
    let bytes = std::fs::metadata(&path).unwrap().len();
    assert!(bytes < 300_000, "snapshot is {bytes} bytes after churn");
    let (_, stj) = snapshot::load_value(&path).unwrap();
    assert!(stj.get("deploy").is_some());

    let donor_status = c.deploy_status().unwrap().to_string();
    let donor_alloc = allocation(&mut c, 1_000_000, 8);

    // revive: serve --restore path with the deploy layer warm-started
    let revived = spawn_deploy_engine(4, Some(path.clone()));
    let mut rc = ParetoClient::connect(revived.addr).unwrap();
    assert_eq!(
        rc.deploy_status().unwrap().to_string(),
        donor_status,
        "restored engine must report identical deployment state"
    );
    let revived_alloc = allocation(&mut rc, 1_000_000, 8);
    assert_eq!(
        revived_alloc, donor_alloc,
        "restored engine must route like the donor"
    );

    let _ = std::fs::remove_dir_all(&dir);
    revived.stop();
    engine.stop();
}
