//! Wire protocol v2 acceptance over real TCP:
//!
//! * a `route_batch` of 64 prompts against the 4-shard engine completes
//!   in ONE socket round-trip (one request line, one response line — not
//!   64), with per-item results in request order and the items fanned out
//!   across every shard;
//! * v1 single-verb requests (no `"v"` field, arm-only addressing) are
//!   still accepted by both serving paths;
//! * errors carry structured codes and echo the request id;
//! * name-based model addressing works end-to-end through the engine's
//!   serialized admin path.

use std::sync::Arc;
use std::time::Duration;

use paretobandit::client::{ClientError, ParetoClient};
use paretobandit::pacer::{PacerConfig, SharedPacer};
use paretobandit::router::{ContextCache, ModelRef, ParetoRouter, Prior, RouterConfig};
use paretobandit::server::{
    Client, EngineConfig, ErrorCode, Metrics, Server, ServerState, ShardedEngine,
};
use paretobandit::sim::hash_features;
use paretobandit::util::json::Json;

const D: usize = 8;
const BUDGET: f64 = 1e-3;

/// Engine whose featurizer rejects prompts containing "POISON", so the
/// `featurize_failed` path is drivable over the wire.
fn spawn_engine(workers: usize) -> ShardedEngine {
    let ledger = Arc::new(SharedPacer::new(PacerConfig::new(BUDGET)));
    let build = move |shard: usize| {
        let mut router =
            ParetoRouter::new(RouterConfig::tabula_rasa(D, Some(BUDGET), 70 + shard as u64));
        router.use_shared_pacer(ledger.clone());
        router.add_model("llama", 0.10, 0.10, Prior::Cold);
        router.add_model("mistral", 0.40, 1.60, Prior::Cold);
        ServerState::new(
            router,
            ContextCache::new(4096),
            Box::new(|t: &str| {
                anyhow::ensure!(!t.contains("POISON"), "poisoned prompt");
                Ok(hash_features(t, D))
            }),
            Arc::new(Metrics::new()),
        )
    };
    let cfg = EngineConfig::new(workers).merge_every(Duration::from_millis(25));
    ShardedEngine::spawn("127.0.0.1:0", cfg, build).unwrap()
}

fn single_server() -> Server {
    Server::spawn("127.0.0.1:0", || {
        let mut router = ParetoRouter::new(RouterConfig::tabula_rasa(D, Some(BUDGET), 7));
        router.add_model("llama", 0.10, 0.10, Prior::Cold);
        router.add_model("mistral", 0.40, 1.60, Prior::Cold);
        ServerState::new(
            router,
            ContextCache::new(4096),
            Box::new(|t: &str| Ok(hash_features(t, D))),
            Arc::new(Metrics::new()),
        )
    })
    .unwrap()
}

fn api_code(e: &ClientError) -> Option<ErrorCode> {
    match e {
        ClientError::Api(e) => Some(e.code),
        ClientError::Transport(_) => None,
    }
}

#[test]
fn route_batch_of_64_is_one_round_trip_in_request_order() {
    let engine = spawn_engine(4);

    // ONE raw line in, ONE raw line out: the batch of 64 costs a single
    // socket round-trip, not 64 (Client::call = one write + one read).
    let mut raw = Client::connect(&engine.addr).unwrap();
    let items: Vec<Json> = (0..64u64)
        .map(|i| {
            Json::obj(vec![
                ("id", Json::Num(i as f64)),
                ("prompt", Json::Str(format!("batch prompt number {i}"))),
            ])
        })
        .collect();
    let resp = raw
        .call(&Json::obj(vec![
            ("op", Json::Str("route_batch".into())),
            ("v", Json::Num(2.0)),
            ("id", Json::Num(4242.0)),
            ("items", Json::Arr(items)),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    assert_eq!(resp.get("v").unwrap().as_f64(), Some(2.0));
    assert_eq!(resp.get("id").unwrap().as_f64(), Some(4242.0), "batch id echoed");
    let results = resp.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 64);
    let mut shards_seen = [false; 4];
    for (k, r) in results.iter().enumerate() {
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "item {k}: {r:?}");
        assert_eq!(
            r.get("id").unwrap().as_f64(),
            Some(k as f64),
            "per-item results must be in request order"
        );
        shards_seen[r.get("shard").unwrap().as_f64().unwrap() as usize] = true;
    }
    assert!(
        shards_seen.iter().all(|&s| s),
        "64 items over 4 shards must fan out to every shard: {shards_seen:?}"
    );

    // the batched routes are owned shard-correctly: feedback for all 64
    // (itself one round-trip) finds each item's shard
    let mut c = ParetoClient::connect(engine.addr).unwrap();
    let fb: Vec<(u64, f64, f64)> = (0..64).map(|i| (i, 0.8, 2e-4)).collect();
    for ack in c.feedback_batch(&fb).unwrap() {
        ack.unwrap();
    }
    let m = c.metrics().unwrap();
    assert_eq!(m.get("requests").unwrap().as_f64(), Some(64.0));
    assert_eq!(m.get("feedbacks").unwrap().as_f64(), Some(64.0));
    let per_shard = m.get("per_shard").unwrap().as_arr().unwrap();
    assert_eq!(per_shard.len(), 4);
    for s in per_shard {
        assert_eq!(s.as_f64(), Some(16.0), "exact round-robin split of the batch");
    }
    engine.stop();
}

#[test]
fn v1_single_verb_requests_still_accepted_by_the_engine() {
    let engine = spawn_engine(2);
    let mut raw = Client::connect(&engine.addr).unwrap();
    // exactly the pre-v2 wire shapes: no "v", arm-only addressing
    let r = raw
        .call(&Json::obj(vec![
            ("op", Json::Str("route".into())),
            ("id", Json::Num(1.0)),
            ("prompt", Json::Str("v1 client prompt".into())),
        ]))
        .unwrap();
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
    let f = raw
        .call(&Json::obj(vec![
            ("op", Json::Str("feedback".into())),
            ("id", Json::Num(1.0)),
            ("reward", Json::Num(0.9)),
            ("cost", Json::Num(1e-4)),
        ]))
        .unwrap();
    assert_eq!(f.get("ok").unwrap().as_bool(), Some(true), "{f:?}");
    let a = raw
        .call(&Json::obj(vec![
            ("op", Json::Str("add_model".into())),
            ("name", Json::Str("flash".into())),
            ("price_in", Json::Num(0.3)),
            ("price_out", Json::Num(2.5)),
        ]))
        .unwrap();
    assert_eq!(a.get("arm").unwrap().as_f64(), Some(2.0));
    let d = raw
        .call(&Json::obj(vec![
            ("op", Json::Str("delete_model".into())),
            ("arm", Json::Num(2.0)),
        ]))
        .unwrap();
    assert_eq!(d.get("ok").unwrap().as_bool(), Some(true), "{d:?}");
    let s = raw
        .call(&Json::obj(vec![
            ("op", Json::Str("set_budget".into())),
            ("budget", Json::Num(2e-3)),
        ]))
        .unwrap();
    assert_eq!(s.get("ok").unwrap().as_bool(), Some(true), "{s:?}");
    // v1 error contract: "error" is still a plain string; v2 adds the
    // code and the echoed id next to it
    let e = raw
        .call(&Json::obj(vec![
            ("op", Json::Str("feedback".into())),
            ("id", Json::Num(1.0)),
            ("reward", Json::Num(0.9)),
            ("cost", Json::Num(1e-4)),
        ]))
        .unwrap();
    assert_eq!(e.get("ok").unwrap().as_bool(), Some(false));
    assert!(e.get("error").unwrap().as_str().is_some());
    assert_eq!(e.get("code").unwrap().as_str(), Some("unknown_id"));
    assert_eq!(e.get("id").unwrap().as_f64(), Some(1.0));
    engine.stop();
}

#[test]
fn structured_error_codes_over_the_wire() {
    let engine = spawn_engine(2);
    let mut c = ParetoClient::connect(engine.addr).unwrap();

    // featurize_failed, echoing the route id
    let e = c.route(5, "POISON pill").unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::FeaturizeFailed));
    // ...and the poisoned id was never claimed
    let e = c.feedback(5, 0.5, 1e-4).unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::UnknownId));

    // duplicate_model through the engine's serialized admin path
    let e = c.add_model("llama", 0.1, 0.1, None).unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::DuplicateModel));

    // unknown_model by name and by arm
    let e = c.delete_model(&ModelRef::Name("no-such-model".into())).unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::UnknownModel));
    let e = c.reprice(&ModelRef::Arm(99), 0.1, 0.1).unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::UnknownModel));

    // bad_request from the raw wire: unknown op + id echo survives the
    // full engine path
    let mut raw = Client::connect(&engine.addr).unwrap();
    let r = raw
        .call(&Json::obj(vec![
            ("op", Json::Str("frobnicate".into())),
            ("id", Json::Num(31.0)),
        ]))
        .unwrap();
    assert_eq!(r.get("code").unwrap().as_str(), Some("bad_request"));
    assert_eq!(r.get("id").unwrap().as_f64(), Some(31.0));
    // malformed JSON still gets a structured error, connection survives
    let r = raw.call(&Json::Str("not an object".into())).unwrap();
    assert_eq!(r.get("code").unwrap().as_str(), Some("bad_request"));
    let m = c.metrics().unwrap();
    assert!(m.get("requests").is_some());
    engine.stop();
}

#[test]
fn name_addressing_end_to_end_on_the_engine() {
    let engine = spawn_engine(3);
    let mut c = ParetoClient::connect(engine.addr).unwrap();
    let arm = c.add_model("gemini-2.5-flash", 0.3, 2.5, Some((20.0, 0.5))).unwrap();
    assert_eq!(arm, 2);
    // reprice by name and by arm hit the same slot
    assert_eq!(c.reprice(&ModelRef::Name("gemini-2.5-flash".into()), 0.2, 2.0).unwrap(), arm);
    assert_eq!(c.reprice(&ModelRef::Arm(arm), 0.25, 2.1).unwrap(), arm);
    // serve some traffic across the swap to prove slots stay aligned
    for i in 0..12u64 {
        c.route(i, &format!("hot traffic {i}")).unwrap();
        c.feedback(i, 0.8, 2e-4).unwrap();
    }
    // delete by name retires the slot on every shard; re-adding the name
    // gets a FRESH slot (retired slots are never reused)
    assert_eq!(c.delete_model(&ModelRef::Name("gemini-2.5-flash".into())).unwrap(), arm);
    let e = c.delete_model(&ModelRef::Name("gemini-2.5-flash".into())).unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::UnknownModel));
    let arm2 = c.add_model("gemini-2.5-flash", 0.3, 2.5, None).unwrap();
    assert_eq!(arm2, 3, "retired slot must not be reused");
    assert_eq!(c.delete_model(&ModelRef::Name("gemini-2.5-flash".into())).unwrap(), arm2);
    engine.stop();
}

#[test]
fn sdk_works_against_the_single_worker_server_too() {
    // the same typed SDK drives the reference server: the two serving
    // paths share one protocol implementation and cannot drift
    let server = single_server();
    let mut c = ParetoClient::connect(server.addr).unwrap();
    let items: Vec<(u64, String)> = (0..8).map(|i| (i, format!("prompt {i}"))).collect();
    let routed = c.route_batch(&items).unwrap();
    for (k, r) in routed.iter().enumerate() {
        assert_eq!(r.as_ref().unwrap().id, k as u64);
        assert_eq!(r.as_ref().unwrap().shard, 0);
    }
    let fb: Vec<(u64, f64, f64)> = (0..8).map(|i| (i, 0.7, 1e-4)).collect();
    for ack in c.feedback_batch(&fb).unwrap() {
        ack.unwrap();
    }
    // single-worker sync: well-defined no-op answering as a 1-shard engine
    let s = c.sync().unwrap();
    assert_eq!(s.synced_shards, 1);
    // name addressing parity with the engine
    let arm = c.add_model("flash", 0.3, 2.5, None).unwrap();
    assert_eq!(c.delete_model(&ModelRef::Name("flash".into())).unwrap(), arm);
    let m = c.metrics().unwrap();
    assert_eq!(m.get("requests").unwrap().as_f64(), Some(8.0));
    server.stop();
}
