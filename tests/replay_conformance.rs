//! End-to-end replay conformance: capture a stationary exp1-style run
//! through a 4-shard engine with per-shard decision logs (the `serve
//! --log-dir` wiring, shared capture clock and all), replay the capture
//! with the same policy, and assert the decision sequence and λ
//! trajectory reproduce bit-identically.  Also: the capture's decision
//! records agree with what the client was told, counterfactual replay of
//! a different policy runs over the same capture, and `replay
//! --export-priors` output loads through the `serve --restore` path.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Duration;

use paretobandit::client::ParetoClient;
use paretobandit::log::{
    export_priors, read_log_dir, replay_policy, CaptureMeta, LogWriter, ModelMeta, Record,
    DEFAULT_SEGMENT_BYTES,
};
use paretobandit::pacer::{PacerConfig, SharedPacer};
use paretobandit::router::{build_policy, BuildCtx, ContextCache, ModelRef, ModelSpec};
use paretobandit::scenario::snapshot;
use paretobandit::server::{EngineConfig, Metrics, ServerState, ShardedEngine};
use paretobandit::sim::hash_features;
use paretobandit::util::rng::Rng;

const D: usize = 6;
const BUDGET: f64 = 6.6e-4;
const POLICY: &str = "paretobandit";

fn table1() -> Vec<ModelSpec> {
    vec![
        ModelSpec::new("llama-3.1-8b", 0.10, 0.10).with_prior(25.0, 0.7),
        ModelSpec::new("mistral-large", 0.40, 1.60).with_prior(25.0, 0.7),
        ModelSpec::new("gemini-2.5-pro", 1.25, 10.0).with_prior(25.0, 0.7),
    ]
}

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pb_replay_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Spawn a 4-shard engine exactly the way `serve --log-dir` builds one:
/// cold Table-1 portfolio with priors, seed `42 + shard`, one shared
/// budget ledger, one shared capture clock, a `LogWriter` per shard with
/// a cold-rebuild header.  Merge cycles are pushed out to an hour so
/// none fires mid-capture (an unlogged cross-shard posterior adoption
/// would break bit-identity; see docs/replay.md).
fn spawn_logged_engine(log_dir: &std::path::Path) -> ShardedEngine {
    let ledger = Arc::new(SharedPacer::new(PacerConfig::new(BUDGET)));
    let clock = Arc::new(AtomicU64::new(0));
    let dir = log_dir.to_path_buf();
    let build = move |shard: usize| {
        let models = table1();
        let mut host = build_policy(
            POLICY,
            &BuildCtx {
                d: D,
                budget: Some(BUDGET),
                seed: 42 + shard as u64,
                models: &models,
            },
        )
        .expect("build policy");
        host.use_shared_pacer(ledger.clone());
        let mut state = ServerState::with_host(
            host,
            ContextCache::new(65536),
            Box::new(|t: &str| Ok(hash_features(t, D))),
            Arc::new(Metrics::new()),
        );
        let meta = CaptureMeta {
            shard: shard as u32,
            d: D as u32,
            seed: 42 + shard as u64,
            budget: Some(BUDGET),
            policy: POLICY.to_string(),
            warm: false,
            models: models
                .iter()
                .map(|m| {
                    Some(ModelMeta {
                        name: m.name.clone(),
                        price_in: m.price_in,
                        price_out: m.price_out,
                        prior: m.prior,
                    })
                })
                .collect(),
        };
        let w = LogWriter::with_clock(&dir, meta, DEFAULT_SEGMENT_BYTES, clock.clone())
            .expect("log writer");
        state.attach_log(w);
        state
    };
    ShardedEngine::spawn(
        "127.0.0.1:0",
        EngineConfig::new(4).merge_every(Duration::from_secs(3600)),
        build,
    )
    .unwrap()
}

/// Deterministic per-arm reward/cost schedule (the exp1-style stationary
/// world: distinct means make the stream informative).
fn judge(rng: &mut Rng, arm: usize) -> (f64, f64) {
    let means = [0.55, 0.9, 0.7, 0.8];
    let costs = [2.9e-5, 5.3e-4, 1.5e-2, 2.0e-4];
    let m = means.get(arm).copied().unwrap_or(0.5);
    let c = costs.get(arm).copied().unwrap_or(1e-4);
    ((m + 0.03 * rng.normal()).clamp(0.0, 1.0), c)
}

#[test]
fn captured_run_replays_bit_identically_and_exports_loadable_priors() {
    let dir = temp_dir("e2e");
    let engine = spawn_logged_engine(&dir);
    let mut c = ParetoClient::connect(engine.addr).unwrap();
    let mut rng = Rng::new(2024);
    // id → (served arm, λ bits) as the client observed them
    let mut served: HashMap<u64, (usize, u64)> = HashMap::new();

    // phase A: stationary singles
    for i in 0..60u64 {
        let r = c.route(i, &format!("stationary prompt {i}")).unwrap();
        let (reward, cost) = judge(&mut rng, r.arm);
        c.feedback(i, reward, cost).unwrap();
        served.insert(i, (r.arm, r.lambda.to_bits()));
    }
    // runtime onboarding, then more traffic across 4 arms
    let flash = c.add_model("flash", 0.3, 2.5, Some((20.0, 0.5))).unwrap();
    assert_eq!(flash, 3);
    for i in 100..140u64 {
        let r = c.route(i, &format!("onboarded prompt {i}")).unwrap();
        let (reward, cost) = judge(&mut rng, r.arm);
        c.feedback(i, reward, cost).unwrap();
        served.insert(i, (r.arm, r.lambda.to_bits()));
    }
    // price drift + budget change mid-capture
    c.reprice(&ModelRef::Name("gemini-2.5-pro".into()), 0.6, 5.0).unwrap();
    c.set_budget(BUDGET * 1.5).unwrap();
    // a vectorized batch (one shard, one eligibility pass)
    let items: Vec<(u64, String)> = (200..208u64).map(|i| (i, format!("batch {i}"))).collect();
    for r in c.route_batch(&items).unwrap() {
        let r = r.unwrap();
        let (reward, cost) = judge(&mut rng, r.arm);
        c.feedback(r.id, reward, cost).unwrap();
        served.insert(r.id, (r.arm, r.lambda.to_bits()));
    }
    // merge cycle at the very end: logs the sync barriers + flushes
    c.sync().unwrap();
    engine.stop();

    // --- capture fidelity: the log records what the client was told
    let log = read_log_dir(&dir).unwrap();
    assert!(!log.damaged(), "clean shutdown must leave clean segments");
    assert_eq!(log.shards.len(), 4);
    let mut n_dec = 0usize;
    let mut n_fb = 0usize;
    let mut n_barrier = 0usize;
    for (_, rec) in log.merged() {
        match rec {
            Record::Decision(d) => {
                n_dec += 1;
                let (arm, lambda_bits) = served[&d.request_id];
                assert_eq!(d.arm as usize, arm, "id {}: logged arm drifted", d.request_id);
                assert_eq!(
                    d.lambda.to_bits(),
                    lambda_bits,
                    "id {}: logged λ drifted",
                    d.request_id
                );
                assert_eq!(d.x.len(), D);
                assert!(!d.eligible.is_empty(), "id {}: empty eligible set", d.request_id);
                assert!(
                    d.eligible.iter().any(|e| e.slot == d.arm),
                    "id {}: served arm missing from the eligible table",
                    d.request_id
                );
            }
            Record::Feedback(f) => {
                n_fb += 1;
                assert!(f.queued, "sharded feedback is queued for the merge cycle");
                assert_eq!(f.arm as usize, served[&f.request_id].0);
            }
            Record::Admin(a) => {
                if matches!(a.op, paretobandit::log::AdminOp::SyncBarrier) {
                    n_barrier += 1;
                }
            }
            Record::Header(_) => unreachable!("headers are not records"),
        }
    }
    assert_eq!(n_dec, served.len());
    assert_eq!(n_fb, served.len());
    assert_eq!(n_barrier, 4, "one sync barrier per shard");

    // --- bit-identical replay of the captured policy
    let rep = replay_policy(&log, POLICY).unwrap();
    assert_eq!(rep.decisions, served.len() as u64);
    assert_eq!(rep.scored, served.len() as u64);
    assert_eq!(
        rep.diverged, 0,
        "decision sequence must reproduce bit-identically: {:?}",
        rep.divergences
    );
    assert_eq!(rep.matched, rep.scored);
    assert_eq!(rep.lambda_drift, 0, "λ trajectory must reproduce bit-identically");
    assert!(!rep.hit_restore);
    assert!(rep.est_spend > 0.0 && rep.est_spend.is_finite());

    // --- counterfactual replay of a different policy over the same log
    let cheap = replay_policy(&log, "fixed:llama-3.1-8b").unwrap();
    assert_eq!(cheap.decisions, rep.decisions);
    assert_eq!(cheap.scored, rep.scored);
    // the capture explored past llama, so the fixed policy must diverge
    // somewhere and be charged declared prices there
    assert!(cheap.diverged > 0);
    assert!(cheap.matched < cheap.scored);
    assert!(cheap.est_spend > 0.0 && cheap.est_spend.is_finite());

    // --- exported priors load through the serve --restore path
    let snap_path = dir.join("fitted.snap.json");
    let mut rep = rep;
    let (kind, st) = export_priors(&mut rep).unwrap();
    assert_eq!(kind, POLICY);
    snapshot::save_value(&snap_path, Some(&kind), &st).unwrap();
    let (tag, loaded) = snapshot::load_value(&snap_path).unwrap();
    assert_eq!(tag.as_deref(), Some(POLICY));
    // mirror serve --restore: trial-restore on a probe host built with an
    // empty portfolio (the snapshot carries the portfolio)
    let mut probe = build_policy(
        POLICY,
        &BuildCtx {
            d: D,
            budget: Some(BUDGET),
            seed: 0,
            models: &[],
        },
    )
    .unwrap();
    probe.restore_state(&loaded).expect("snapshot must restore");
    assert_eq!(
        probe.registry().n_active(),
        4,
        "restored portfolio carries the onboarded model too"
    );
    assert!(probe.step() > 0, "restored host carries the fitted clock");
    // the restored host routes without panicking on a fresh context
    let x: Vec<f64> = (0..D).map(|i| if i == D - 1 { 1.0 } else { 0.1 }).collect();
    let d = probe.route(&x);
    assert!(probe.registry().is_active(d.arm));

    let _ = std::fs::remove_dir_all(&dir);
}
