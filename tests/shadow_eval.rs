//! Live shadow evaluation on the sharded engine (acceptance test for
//! `serve --policy paretobandit --shadow random,epsilon`):
//!
//! * served traffic must be bit-identical to a shadowless engine with
//!   the same per-shard seeds — shadows observe, they never steer;
//! * every shadow's counterfactual quality/cost/λ series must show up in
//!   `metrics` and `compare`, scored on the full stream, and diverge
//!   from the served decisions.

use std::sync::Arc;
use std::time::Duration;

use paretobandit::client::ParetoClient;
use paretobandit::pacer::{PacerConfig, SharedPacer};
use paretobandit::router::{build_policy, BuildCtx, ContextCache, ModelSpec};
use paretobandit::server::{EngineConfig, Metrics, ServerState, ShardedEngine};
use paretobandit::sim::hash_features;

const D: usize = 6;
const BUDGET: f64 = 6.6e-4;

fn table1() -> Vec<ModelSpec> {
    vec![
        ModelSpec::new("llama-3.1-8b", 0.10, 0.10),
        ModelSpec::new("mistral-large", 0.40, 1.60),
        ModelSpec::new("gemini-2.5-pro", 1.25, 10.0),
    ]
}

/// 4-shard engine; a 60 s merge interval keeps timer-driven merges out of
/// the test window so both engines stay bit-comparable.
fn spawn(workers: usize, shadows: &'static [&'static str]) -> ShardedEngine {
    let ledger = Arc::new(SharedPacer::new(PacerConfig::new(BUDGET)));
    let build = move |shard: usize| {
        let models = table1();
        let ctx = BuildCtx {
            d: D,
            budget: Some(BUDGET),
            seed: 42 + shard as u64,
            models: &models,
        };
        let mut host = build_policy("paretobandit", &ctx).unwrap();
        host.use_shared_pacer(ledger.clone());
        let mut st = ServerState::with_host(
            host,
            ContextCache::new(4096),
            Box::new(|t: &str| Ok(hash_features(t, D))),
            Arc::new(Metrics::new()),
        );
        for (i, spec) in shadows.iter().enumerate() {
            st.add_shadow(spec, D, Some(BUDGET), 9000 + 100 * (i as u64 + 1) + shard as u64)
                .unwrap();
        }
        st
    };
    ShardedEngine::spawn(
        "127.0.0.1:0",
        EngineConfig::new(workers).merge_every(Duration::from_secs(60)),
        build,
    )
    .unwrap()
}

#[test]
fn four_shard_shadows_diverge_while_served_traffic_matches_baseline() {
    let shadowed = spawn(4, &["random", "epsilon"]);
    let baseline = spawn(4, &[]);
    let mut ca = ParetoClient::connect(shadowed.addr).unwrap();
    let mut cb = ParetoClient::connect(baseline.addr).unwrap();
    let mut served_a = Vec::new();
    let mut served_b = Vec::new();
    for i in 0..120u64 {
        let prompt = format!("shadow eval prompt number {i}");
        let ra = ca.route(i, &prompt).unwrap();
        let rb = cb.route(i, &prompt).unwrap();
        served_a.push((ra.shard, ra.arm));
        served_b.push((rb.shard, rb.arm));
        // overspend so λ visibly moves on the served pacer
        ca.feedback(i, 0.8, 2e-3).unwrap();
        cb.feedback(i, 0.8, 2e-3).unwrap();
    }
    assert_eq!(
        served_a, served_b,
        "shadow evaluation must not perturb served traffic"
    );

    let rep = ca.compare().unwrap();
    let served = rep.get("served").unwrap();
    assert_eq!(served.get("policy").unwrap().as_str(), Some("ParetoBandit"));
    assert_eq!(served.get("requests").unwrap().as_f64(), Some(120.0));
    assert!(served.get("mean_cost").unwrap().as_f64().unwrap() > 0.0);
    let shadows = rep.get("shadows").unwrap().as_arr().unwrap();
    assert_eq!(shadows.len(), 2);
    assert_eq!(shadows[0].get("policy").unwrap().as_str(), Some("Random"));
    assert_eq!(
        shadows[1].get("policy").unwrap().as_str(),
        Some("EpsilonGreedy")
    );
    for s in shadows {
        assert_eq!(s.get("decisions").unwrap().as_f64(), Some(120.0));
        assert_eq!(s.get("scored").unwrap().as_f64(), Some(120.0));
        assert!(s.get("est_mean_cost").unwrap().as_f64().unwrap() > 0.0);
        assert!(s.get("lambda").unwrap().as_f64().is_some());
    }
    // a uniform-random shadow agreeing with the served policy on all 120
    // decisions has probability ~3^-120: its series must diverge
    let random_rate = shadows[0].get("match_rate").unwrap().as_f64().unwrap();
    assert!(random_rate < 1.0, "random shadow cannot match served traffic: {random_rate}");

    // the same per-policy series ride the metrics snapshot
    let m = ca.metrics().unwrap();
    assert_eq!(m.get("policy").unwrap().as_str(), Some("ParetoBandit"));
    assert!(m.get("lambda").unwrap().as_f64().is_some());
    assert_eq!(m.get("shadows").unwrap().as_arr().unwrap().len(), 2);
    let mb = cb.metrics().unwrap();
    assert_eq!(mb.get("shadows").unwrap().as_arr().unwrap().len(), 0);

    shadowed.stop();
    baseline.stop();
}

#[test]
fn shadows_follow_hot_swap_and_survive_batch_verbs() {
    let engine = spawn(2, &["fixed:mistral-large"]);
    let mut c = ParetoClient::connect(engine.addr).unwrap();
    // batch verbs keep shadow scoring intact
    let items: Vec<(u64, String)> = (0..16).map(|i| (i, format!("batch item {i}"))).collect();
    let routed = c.route_batch(&items).unwrap();
    assert_eq!(routed.len(), 16);
    let fb: Vec<(u64, f64, f64)> = (0..16).map(|i| (i, 0.8, 1e-4)).collect();
    for ack in c.feedback_batch(&fb).unwrap() {
        ack.unwrap();
    }
    // hot-swap flows into the shadows (slot ids stay comparable)
    let arm = c.add_model("gemini-2.5-flash", 0.30, 2.50, None).unwrap();
    assert_eq!(arm, 3);
    for i in 16..32u64 {
        c.route(i, &format!("post swap {i}")).unwrap();
        c.feedback(i, 0.8, 1e-4).unwrap();
    }
    let rep = c.compare().unwrap();
    let shadows = rep.get("shadows").unwrap().as_arr().unwrap();
    assert_eq!(shadows.len(), 1);
    assert_eq!(
        shadows[0].get("policy").unwrap().as_str(),
        Some("Fixed(mistral-large)")
    );
    assert_eq!(shadows[0].get("decisions").unwrap().as_f64(), Some(32.0));
    assert_eq!(shadows[0].get("scored").unwrap().as_f64(), Some(32.0));
    engine.stop();
}
