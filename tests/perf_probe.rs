//! Perf probes backing EXPERIMENTS.md §Perf (run with --ignored), plus a
//! CI-safe latency guard that runs by default: its threshold comes from
//! the PB_ROUTE_BUDGET_US env var (the CRITERION_MEASUREMENT_TIME
//! override pattern), so slow shared runners loosen the budget instead of
//! flaking.
use paretobandit::linalg::Mat;
use paretobandit::router::{ParetoRouter, Prior, RouterConfig};
use paretobandit::util::bench::{bench_batched, black_box};
use paretobandit::util::env_or;
use paretobandit::util::rng::Rng;

/// Not #[ignore]: guards against gross routing-path regressions (e.g. an
/// accidental O(d^3) per decision) on every `cargo test`.  The default
/// budget is ~100x the release-mode figure so debug builds and loaded CI
/// runners pass; tighten via PB_ROUTE_BUDGET_US when measuring for real.
#[test]
fn route_decision_within_latency_budget() {
    let budget_us: f64 = env_or("PB_ROUTE_BUDGET_US", 2_000.0);
    let samples: usize = env_or("PB_PERF_SAMPLES", 200);
    let d = 26;
    let mut rng = Rng::new(1);
    let xs: Vec<Vec<f64>> = (0..256).map(|_| ctx(&mut rng, d)).collect();
    let mut r = mk_router(d);
    // warm the posteriors so the measured path includes realistic scoring
    for i in 0..600usize {
        let x = &xs[i & 255];
        let dec = r.route(x);
        r.feedback(dec.arm, x, 0.8, 2e-4);
    }
    let mut i = 0usize;
    let stats = bench_batched(50, samples, 32, || {
        black_box(r.route(&xs[i & 255]).arm);
        i += 1;
    });
    let p50_us = stats.p50_ns / 1e3;
    assert!(
        p50_us <= budget_us,
        "route() p50 {p50_us:.1}us exceeds PB_ROUTE_BUDGET_US={budget_us}us"
    );
}

fn ctx(rng: &mut Rng, d: usize) -> Vec<f64> {
    let mut x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    x[d - 1] = 1.0;
    x
}

fn mk_router(d: usize) -> ParetoRouter {
    let mut r = ParetoRouter::new(RouterConfig::paretobandit(d, 6.6e-4, 1));
    r.add_model("a", 0.10, 0.10, Prior::Cold);
    r.add_model("b", 0.40, 1.60, Prior::Cold);
    r.add_model("c", 1.25, 10.0, Prior::Cold);
    r
}

#[test]
#[ignore]
fn probe_route_alloc_variant() {
    // (a) production route (scratch buffers reused on the router)
    let mut rng = Rng::new(2);
    let d = 26;
    let xs: Vec<Vec<f64>> = (0..256).map(|_| ctx(&mut rng, d)).collect();
    let mut r = mk_router(d);
    let mut i = 0usize;
    let prod = bench_batched(200, 300, 64, || {
        black_box(r.route(&xs[i & 255]).arm);
        i += 1;
    });
    // (b) simulated alloc-per-call variant: same math, fresh Vecs each call
    let r2 = mk_router(d);
    let mut j = 0usize;
    let alloc = bench_batched(200, 300, 64, || {
        let x = &xs[j & 255];
        let mut ids: Vec<usize> = Vec::new();
        let mut scores: Vec<f64> = Vec::new();
        for id in 0..3usize {
            ids.push(id);
            let arm = r2.arm(id).unwrap();
            let infl = arm.staleness_inflation(0.997, 200.0, 1);
            scores.push(arm.predict(x) + 0.01 * (arm.variance(x) * infl).sqrt());
        }
        black_box(scores.iter().cloned().fold(f64::MIN, f64::max));
        j += 1;
    });
    println!("route (reused buffers): {:.0} ns | alloc-per-call variant: {:.0} ns",
        prod.mean_ns, alloc.mean_ns);
}

#[test]
#[ignore]
fn probe_refresh_cost() {
    // marginal cost of the every-512 exact refresh in update()
    let d = 26;
    let mut rng = Rng::new(3);
    let xs: Vec<Vec<f64>> = (0..256).map(|_| ctx(&mut rng, d)).collect();
    let mut r = mk_router(d);
    let mut i = 0usize;
    let upd = bench_batched(200, 300, 64, || {
        r.feedback(i % 3, &xs[i & 255], 0.8, 5e-4);
        i += 1;
    });
    // a standalone Cholesky refresh at d=26 for scale
    let a = Mat::from_rows(d, paretobandit::util::prop::spd(&mut Rng::new(4), d, 1.0));
    let chol = bench_batched(50, 100, 16, || {
        black_box(paretobandit::linalg::Cholesky::factor(&a).unwrap().inverse());
    });
    println!("update mean: {:.0} ns | exact refresh: {:.0} ns (amortised /512 = {:.1} ns)",
        upd.mean_ns, chol.mean_ns, chol.mean_ns / 512.0);
}

#[test]
#[ignore]
fn probe_pallas_scorer_vs_native() {
    use paretobandit::runtime::{default_artifacts_dir, ArmBank, ArtifactMeta, Runtime, Scorer};
    let dir = default_artifacts_dir();
    if !dir.join("meta.json").exists() { return; }
    let rt = Runtime::cpu().unwrap();
    let meta = ArtifactMeta::load(&dir).unwrap();
    let s = Scorer::load(&rt, &meta).unwrap();
    let mut rng = Rng::new(5);
    let d = 26;
    let mut bank = ArmBank::empty(s.k_max, d);
    for k in 0..3 {
        let a = Mat::from_rows(d, paretobandit::util::prop::spd(&mut rng, d, 1.0));
        bank.set_slot(k, &a.inverse_gauss_jordan().unwrap(),
                      &vec![0.1; d], 1.0, 0.1 * k as f64);
    }
    let x = ctx(&mut rng, d);
    let pjrt1 = bench_batched(20, 60, 4, || {
        black_box(s.score_one(&bank, 0.05, &x).unwrap());
    });
    let xs16: Vec<Vec<f64>> = (0..16).map(|_| ctx(&mut rng, d)).collect();
    let pjrt16 = bench_batched(20, 60, 4, || {
        black_box(s.score_many(&bank, 0.05, &xs16).unwrap());
    });
    let mut r = mk_router(d);
    let mut i = 0usize;
    let xs: Vec<Vec<f64>> = (0..64).map(|_| ctx(&mut rng, d)).collect();
    let native = bench_batched(100, 200, 64, || {
        black_box(r.route(&xs[i & 63]).arm);
        i += 1;
    });
    println!("PJRT scorer b=1: {:.1} us | b=16: {:.1} us ({:.2} us/row) | native route: {:.2} us",
        pjrt1.mean_ns / 1e3, pjrt16.mean_ns / 1e3, pjrt16.mean_ns / 16.0 / 1e3,
        native.mean_ns / 1e3);
}
