//! Wire-level scenario operations on the sharded engine: `inject`,
//! `snapshot`, `restore` driven through a real 4-shard deployment over
//! TCP, the warm-restart acceptance check (a restored engine's routing
//! distribution matches the donor where a cold engine's does not), and
//! the registry hot-swap churn path (remove → re-add of the same name).

use std::sync::Arc;
use std::time::Duration;

use paretobandit::client::{ClientError, ParetoClient};
use paretobandit::pacer::{PacerConfig, SharedPacer};
use paretobandit::router::{ContextCache, ModelRef, ParetoRouter, Prior, RouterConfig};
use paretobandit::scenario::{snapshot, Event};
use paretobandit::server::{EngineConfig, ErrorCode, Metrics, ServerState, ShardedEngine};
use paretobandit::sim::hash_features;

const D: usize = 8;
const BUDGET: f64 = 1e-3;

/// 4-shard engine over a two-model portfolio; `restore_from` warm-starts
/// every shard from a snapshot file (the `serve --restore` builder path).
fn spawn_engine(workers: usize, restore_from: Option<std::path::PathBuf>) -> ShardedEngine {
    let ledger = Arc::new(SharedPacer::new(PacerConfig::new(BUDGET)));
    let build = move |shard: usize| {
        let mut router =
            ParetoRouter::new(RouterConfig::tabula_rasa(D, Some(BUDGET), 500 + shard as u64));
        router.use_shared_pacer(ledger.clone());
        match &restore_from {
            Some(path) => {
                let st = snapshot::load(path).expect("snapshot file");
                router.restore_state(&st).expect("restore");
                // mirror serve --restore: replicas past shard 0 fork the
                // snapshot's RNG stream
                if shard > 0 {
                    router.fork_rng(shard as u64);
                }
            }
            None => {
                router.add_model("llama", 0.1, 0.1, Prior::Cold);
                router.add_model("mistral", 0.4, 1.6, Prior::Cold);
            }
        }
        ServerState::new(
            router,
            ContextCache::new(4096),
            Box::new(|t: &str| Ok(hash_features(t, D))),
            Arc::new(Metrics::new()),
        )
    };
    ShardedEngine::spawn(
        "127.0.0.1:0",
        EngineConfig::new(workers).merge_every(Duration::from_millis(20)),
        build,
    )
    .unwrap()
}

fn api_code(e: &ClientError) -> Option<ErrorCode> {
    match e {
        ClientError::Api(e) => Some(e.code),
        ClientError::Transport(_) => None,
    }
}

/// Route 100 eval prompts (no feedback) and count per-arm allocations.
fn allocation(c: &mut ParetoClient, id_base: u64, arms: usize) -> Vec<usize> {
    let mut counts = vec![0usize; arms];
    for i in 0..100u64 {
        let r = c.route(id_base + i, &format!("eval prompt {i}")).unwrap();
        counts[r.arm] += 1;
    }
    counts
}

fn temp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pb_wire_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn inject_snapshot_restore_through_a_4_shard_engine() {
    let engine = spawn_engine(4, None);
    let mut c = ParetoClient::connect(engine.addr).unwrap();

    // teach the engine that mistral (arm 1, the pricier model) is good
    // and llama is bad.  A cold router prefers llama (equal exploration
    // bonuses, lower cost penalty), so this preference is only
    // reproducible through warm state — exactly what the restore
    // assertions below need to discriminate.
    for i in 0..300u64 {
        let r = c.route(i, &format!("training prompt {i}")).unwrap();
        let reward = if r.arm == 1 { 0.9 } else { 0.2 };
        c.feedback(i, reward, 1e-4).unwrap();
    }

    // inject: live price drift + a budget change through the one verb
    c.inject(&Event::SetPrice {
        model: "mistral".into(),
        mult: None,
        price_in: Some(0.2),
        price_out: Some(0.8),
    })
    .unwrap();
    c.inject(&Event::SetBudget { budget: BUDGET * 2.0 }).unwrap();
    // environment-side events are rejected with the typed code
    let e = c
        .inject(&Event::DegradeQuality {
            model: "mistral".into(),
            mean_to: Some(0.5),
        })
        .unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::BadRequest));

    // snapshot: merge cycle + shard-0 persist; the file is a valid
    // versioned snapshot holding the global posterior
    let path = temp_path("engine.snap.json");
    let (arms, t) = c.snapshot(path.to_str().unwrap()).unwrap();
    assert_eq!(arms, 2);
    assert!(t > 0, "snapshot step must be past zero, got {t}");
    let st = snapshot::load(&path).unwrap();
    assert_eq!(st.n_active(), 2);
    assert_eq!(
        st.pacer.expect("pacer state").budget,
        BUDGET * 2.0,
        "the injected budget change must be in the snapshot"
    );
    let total_obs: u64 = st
        .slots
        .iter()
        .flatten()
        .map(|s| s.arm.n_obs)
        .sum();
    assert_eq!(total_obs, 300, "global posterior must hold every reward");

    // donor's post-snapshot allocation: dominated by the learned arm 1
    let donor_alloc = allocation(&mut c, 10_000, 2);
    assert!(donor_alloc[1] >= 95, "donor should exploit arm 1: {donor_alloc:?}");

    // a cold engine prefers the cheap arm instead — the warm start is
    // what transfers the learned preference
    let cold = spawn_engine(4, None);
    let mut cc = ParetoClient::connect(cold.addr).unwrap();
    let cold_alloc = allocation(&mut cc, 10_000, 2);
    assert!(
        cold_alloc[1] < 50,
        "cold engine must not know arm 1 is better: {cold_alloc:?}"
    );

    // (a) builder warm start — the serve --restore path
    let warmed = spawn_engine(4, Some(path.clone()));
    let mut wc = ParetoClient::connect(warmed.addr).unwrap();
    let warm_alloc = allocation(&mut wc, 10_000, 2);
    assert_eq!(
        warm_alloc, donor_alloc,
        "restored engine's first-100 routing distribution must match the donor"
    );

    // (b) wire restore verb — warm-start the cold engine in place
    let (rarms, rt) = cc.restore(path.to_str().unwrap()).unwrap();
    assert_eq!(rarms, 2);
    assert_eq!(rt, t);
    let revived_alloc = allocation(&mut cc, 20_000, 2);
    assert_eq!(
        revived_alloc, donor_alloc,
        "wire-restored engine must route like the donor"
    );
    // pending ids from before the restore were dropped with the caches
    let e = cc.feedback(10_005, 0.5, 1e-4).unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::UnknownId));

    // restore failures are typed
    let e = wc.restore("/nonexistent/nope.snap.json").unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::SnapshotIo));
    let e = wc.snapshot("/nonexistent-dir/x/y.snap.json").unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::SnapshotIo));

    let _ = std::fs::remove_file(&path);
    warmed.stop();
    cold.stop();
    engine.stop();
}

#[test]
fn exp2_spec_replays_against_a_live_engine() {
    use paretobandit::exp::ExpEnv;
    use paretobandit::scenario::{run_scenario_wire, RunOptions, ScenarioSpec};
    use paretobandit::sim::FlashScenario;

    // an engine serving the Table-1 portfolio under the simulator's
    // model names, so the spec's set_price events resolve on both sides
    let env = ExpEnv::load(FlashScenario::GoodCheap);
    let d = env.d();
    let ledger = Arc::new(SharedPacer::new(PacerConfig::new(6.6e-4)));
    let build = move |shard: usize| {
        let mut router =
            ParetoRouter::new(RouterConfig::tabula_rasa(d, Some(6.6e-4), 900 + shard as u64));
        router.use_shared_pacer(ledger.clone());
        router.add_model("llama-3.1-8b", 0.10, 0.10, Prior::Cold);
        router.add_model("mistral-large", 0.40, 1.60, Prior::Cold);
        router.add_model("gemini-2.5-pro", 1.25, 10.0, Prior::Cold);
        ServerState::new(
            router,
            ContextCache::new(65536),
            Box::new(move |t: &str| Ok(hash_features(t, d))),
            Arc::new(Metrics::new()),
        )
    };
    let engine = ShardedEngine::spawn(
        "127.0.0.1:0",
        EngineConfig::new(2).merge_every(Duration::from_millis(20)),
        build,
    )
    .unwrap();
    let mut client = ParetoClient::connect(engine.addr).unwrap();
    let spec = ScenarioSpec::load_named("exp2_costdrift").unwrap();
    let run = run_scenario_wire(
        &spec,
        &env,
        &env.world,
        &mut client,
        &RunOptions {
            seed: 1,
            reprice_router: true,
        },
    )
    .unwrap();
    // three 608-step phases, all served over the wire
    assert_eq!(run.phases.len(), 3);
    for ph in &run.phases {
        assert_eq!(ph.len(), 608);
    }
    // the two price events (cut + restore) travelled as injects, plus
    // the two traffic_mix phase boundaries applied locally
    assert_eq!(run.event_log.len(), 4);
    assert!(run.event_log.iter().any(|l| l.starts_with("t=608") && l.contains("set_price")));
    assert!(run.event_log.iter().any(|l| l.starts_with("t=1216") && l.contains("set_price")));
    let m = client.metrics().unwrap();
    assert_eq!(
        m.get("requests").and_then(paretobandit::util::json::Json::as_f64),
        Some(1824.0)
    );
    assert_eq!(
        m.get("feedbacks").and_then(paretobandit::util::json::Json::as_f64),
        Some(1824.0)
    );
    // rewards are real simulator judgments, not garbage
    let mean: f64 = run.flat().iter().map(|s| s.reward).sum::<f64>() / 1824.0;
    assert!(mean > 0.5, "mean reward {mean}");
    engine.stop();
}

#[test]
fn hot_swap_churn_readds_a_retired_name_on_a_fresh_slot() {
    let engine = spawn_engine(4, None);
    let mut c = ParetoClient::connect(engine.addr).unwrap();
    // add → remove → re-add of the same name must never answer
    // duplicate_model off the tombstoned slot; each cycle gets a fresh id
    let first = c.add_model("flash", 0.3, 2.5, None).unwrap();
    assert_eq!(first, 2);
    // while active, a duplicate IS rejected
    let e = c.add_model("flash", 0.3, 2.5, None).unwrap_err();
    assert_eq!(api_code(&e), Some(ErrorCode::DuplicateModel));
    let mut expected = first;
    for cycle in 0..3 {
        assert_eq!(
            c.delete_model(&ModelRef::Name("flash".into())).unwrap(),
            expected,
            "cycle {cycle}: delete resolves the live slot"
        );
        let readded = c.add_model("flash", 0.3, 2.5, None).unwrap();
        assert_eq!(
            readded,
            expected + 1,
            "cycle {cycle}: re-add must land on a fresh slot, not the tombstone"
        );
        expected = readded;
        // traffic keeps flowing across the churn on every shard
        for i in 0..8u64 {
            let id = 1_000 * (cycle as u64 + 1) + i;
            c.route(id, &format!("churn {cycle} prompt {i}")).unwrap();
            c.feedback(id, 0.8, 1e-4).unwrap();
        }
    }
    // the same churn expressed as inject events
    c.inject(&Event::RemoveModel { model: "flash".into() }).unwrap();
    let resp = c
        .inject(&Event::AddModel {
            model: "flash".into(),
            price_in: Some(0.3),
            price_out: Some(2.5),
            n_eff: None,
            r0: None,
        })
        .unwrap();
    assert_eq!(
        resp.get("arm").and_then(paretobandit::util::json::Json::as_f64),
        Some((expected + 1) as f64)
    );
    engine.stop();
}
