//! Concurrent-spend property test for the shared budget ledger: 8 threads
//! hammer one `SharedPacer` in closed loop — each picks the expensive or
//! the cheap option exactly the way the router's two-layer enforcement
//! does (hard ceiling from the lock-free λ read) and pays the realised
//! cost back into the ledger.  The pooled post-warmup mean $/event must
//! never exceed the ceiling by more than the paper's 0.4% tolerance, the
//! ledger must account every cost exactly, and λ must stay projected.

use std::sync::Arc;

use paretobandit::pacer::{PacerConfig, SharedPacer};
use paretobandit::util::env_or;

const BUDGET: f64 = 4e-4;
const CHEAP: f64 = 1e-4;
const EXPENSIVE: f64 = 8e-4;
/// blended $/1k-rate stand-ins driving the ceiling decision: the expensive
/// model is also the priciest in the portfolio (c_max), so any λ > 0
/// excludes it — the same bang-bang the router's candidate filter produces
const EXPENSIVE_RATE: f64 = 2e-3;

#[test]
fn eight_thread_contention_holds_the_ceiling_within_tolerance() {
    let threads = 8usize;
    let iters: u64 = env_or("PB_LEDGER_ITERS", 30_000);
    let warmup = iters / 5;
    let ledger = Arc::new(SharedPacer::new(PacerConfig::new(BUDGET)));

    let mut handles = Vec::new();
    for _ in 0..threads {
        let ledger = ledger.clone();
        handles.push(std::thread::spawn(move || {
            let mut all_spend = 0.0;
            let mut post_spend = 0.0;
            let mut post_n = 0u64;
            for i in 0..iters {
                // two-layer enforcement: expensive allowed only while the
                // dynamic price ceiling admits it
                let cost = if EXPENSIVE_RATE <= ledger.price_ceiling(EXPENSIVE_RATE) {
                    EXPENSIVE
                } else {
                    CHEAP
                };
                ledger.observe_cost(cost);
                all_spend += cost;
                if i >= warmup {
                    post_spend += cost;
                    post_n += 1;
                }
                // λ read path must stay projected at every instant
                let lam = ledger.lambda();
                assert!((0.0..=5.0).contains(&lam) && lam.is_finite(), "λ={lam}");
            }
            (all_spend, post_spend, post_n)
        }));
    }

    let mut all_spend = 0.0;
    let mut post_spend = 0.0;
    let mut post_n = 0u64;
    for h in handles {
        let (a, p, n) = h.join().unwrap();
        all_spend += a;
        post_spend += p;
        post_n += n;
    }

    // exact accounting: every thread's every cost is in the ledger
    assert_eq!(ledger.observations(), threads as u64 * iters);
    let ledger_total = ledger.total_spend();
    assert!(
        (ledger_total - all_spend).abs() <= all_spend * 1e-9,
        "ledger {ledger_total} vs thread-side {all_spend}"
    );

    // the paper's compliance bound: post-warmup pooled mean within 0.4%
    // above the ceiling (the controller's steady state sits at or below it)
    let mean = post_spend / post_n as f64;
    assert!(
        mean <= BUDGET * 1.004,
        "mean ${mean:.6e}/event exceeds ceiling ${BUDGET:.1e} by more than 0.4%"
    );
    assert!(
        mean >= BUDGET * 0.5,
        "controller collapsed to the cheap arm only: ${mean:.6e}/event"
    );
}
