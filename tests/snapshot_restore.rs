//! Snapshot round-trip property tests: a router restored from a capture
//! must be *behaviourally indistinguishable* from its donor — identical
//! routing decisions, λ trajectory and posteriors on any subsequent
//! stream — including after a trip through the on-disk format.

use paretobandit::router::{ParetoRouter, Prior, RouterConfig};
use paretobandit::scenario::snapshot;
use paretobandit::util::prop;
use paretobandit::util::rng::Rng;

const D: usize = 8;

fn ctx(rng: &mut Rng) -> Vec<f64> {
    let mut x: Vec<f64> = (0..D).map(|_| rng.normal()).collect();
    x[D - 1] = 1.0;
    x
}

fn portfolio(cfg: RouterConfig) -> ParetoRouter {
    let mut r = ParetoRouter::new(cfg);
    r.add_model("llama", 0.10, 0.10, Prior::Cold);
    r.add_model("mistral", 0.40, 1.60, Prior::Cold);
    r.add_model("gemini", 1.25, 10.0, Prior::Cold);
    r
}

/// Drive `n` route+feedback steps; returns the decision sequence.
/// (Four entries so a hot-swapped fourth arm is coverable.)
fn drive(r: &mut ParetoRouter, rng: &mut Rng, n: usize) -> Vec<(usize, f64)> {
    let means = [0.75, 0.9, 0.95, 0.85];
    let costs = [2.9e-5, 5.3e-4, 1.5e-2, 3.0e-4];
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let x = ctx(rng);
        let d = r.route(&x);
        let rew = (means[d.arm] + rng.normal() * 0.03).clamp(0.0, 1.0);
        r.feedback(d.arm, &x, rew, costs[d.arm]);
        out.push((d.arm, d.lambda));
    }
    out
}

#[test]
fn restored_router_replays_the_donor_exactly() {
    prop::for_cases(8, 91, |rng, _| {
        let budget = 1e-4 + rng.f64() * 1.5e-3;
        let cfg = RouterConfig::tabula_rasa(D, Some(budget), rng.next_u64());
        let mut donor = portfolio(cfg);
        // warm the donor up, including a hot-swap + a deletion so the
        // capture covers burn-in state and tombstoned slots
        let mut traffic = Rng::new(rng.next_u64());
        drive(&mut donor, &mut traffic, 150);
        donor.add_model("flash", 0.30, 2.50, Prior::Cold);
        drive(&mut donor, &mut traffic, 30);
        donor.delete_model(1);
        drive(&mut donor, &mut traffic, 40);

        // capture → disk → restore into a fresh router (no models added:
        // the portfolio comes from the snapshot)
        let st = donor.export_state();
        let dir = std::env::temp_dir().join(format!("pb_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prop.snap.json");
        snapshot::save(&path, &st).unwrap();
        let loaded = snapshot::load(&path).unwrap();
        assert_eq!(loaded, st, "on-disk roundtrip must be lossless");
        let mut twin = ParetoRouter::new(cfg);
        twin.restore_state(&loaded).unwrap();

        // registry geometry survives: 4 slots, slot 1 tombstoned
        assert_eq!(twin.registry().n_slots(), 4);
        assert!(!twin.registry().is_active(1));
        assert_eq!(twin.registry().find("flash"), Some(3));
        assert_eq!(twin.step(), donor.step());

        // identical subsequent behaviour on an identical stream
        let stream_seed = rng.next_u64();
        let mut s1 = Rng::new(stream_seed);
        let mut s2 = Rng::new(stream_seed);
        let a = drive(&mut donor, &mut s1, 120);
        let b = drive(&mut twin, &mut s2, 120);
        assert_eq!(a, b, "restored router must replay the donor bit-for-bit");
        for id in [0usize, 2, 3] {
            let (da, ta) = (donor.arm(id).unwrap(), twin.arm(id).unwrap());
            assert_eq!(da.n_obs, ta.n_obs);
            let x = ctx(&mut s1);
            assert_eq!(da.predict(&x), ta.predict(&x));
            assert_eq!(da.variance(&x), ta.variance(&x));
        }
        let _ = std::fs::remove_file(&path);
    });
}

#[test]
fn restore_rejects_dimension_mismatch() {
    let mut donor = portfolio(RouterConfig::tabula_rasa(D, Some(1e-3), 1));
    let st = donor.export_state();
    let mut other = ParetoRouter::new(RouterConfig::tabula_rasa(D + 2, Some(1e-3), 1));
    let e = other.restore_state(&st).unwrap_err();
    assert!(e.contains("d="), "{e}");
}

#[test]
fn pacer_duals_survive_the_roundtrip() {
    let budget = 1e-4;
    let mut donor = portfolio(RouterConfig::paretobandit(D, budget, 7));
    let mut traffic = Rng::new(8);
    // overspend so λ is well away from zero
    for _ in 0..300 {
        let x = ctx(&mut traffic);
        let d = donor.route(&x);
        donor.feedback(d.arm, &x, 0.9, 1.5e-2);
    }
    let lam = donor.pacer().unwrap().lambda();
    assert!(lam > 0.5, "precondition: λ={lam}");
    let st = donor.export_state();
    let mut twin = ParetoRouter::new(RouterConfig::paretobandit(D, budget * 10.0, 9));
    twin.restore_state(&st).unwrap();
    // budget AND dual state come from the snapshot, not the new config
    assert_eq!(twin.pacer().unwrap().budget(), budget);
    assert_eq!(twin.pacer().unwrap().lambda(), lam);
    assert_eq!(twin.pacer().unwrap().cbar(), donor.pacer().unwrap().cbar());
}

#[test]
fn snapshot_does_not_disturb_the_donor_posterior_mean() {
    // export_state barriers the cached inverses to the exact Cholesky
    // refresh; the point estimates may only move by the Sherman–Morrison
    // cache drift the refresh removes (bounded well under 5e-3), never
    // by a systematic amount
    let mut r = portfolio(RouterConfig::tabula_rasa(D, Some(6.6e-4), 3));
    let mut traffic = Rng::new(4);
    drive(&mut r, &mut traffic, 200);
    let x = ctx(&mut traffic);
    let before: Vec<f64> = (0..3).map(|id| r.arm(id).unwrap().predict(&x)).collect();
    let _ = r.export_state();
    for (id, b) in before.iter().enumerate() {
        let after = r.arm(id).unwrap().predict(&x);
        assert!(
            (after - b).abs() < 5e-3,
            "arm {id}: predict moved {b} -> {after} across export"
        );
    }
}
