//! Policy API v2 conformance + golden suite.
//!
//! Part 1 — properties every builder in the policy registry must hold
//! (the trait contract from `docs/policies.md`):
//!   1. decisions always land on ACTIVE slots, through remove → re-add
//!      churn (the eligible-set rule);
//!   2. decisions are deterministic under a fixed seed;
//!   3. `export_state` → `restore_state` → bit-identical decisions.
//!
//! Part 2 — golden bit-identity: `ParetoRouter` driven through the
//! hosted v2 trait must reproduce the standalone pre-refactor
//! `route()`/`feedback()` path EXACTLY — on a synthetic stream with
//! admin churn, on an exp1-style stationary stream, and on the exp2
//! cost-drift scenario timeline.
//!
//! Part 3 — replay-based goldens: every builder policy replayed over a
//! deterministic fixture capture (the decision-log format from
//! `rust/src/log/`) produces a stable quality/spend summary, and the
//! captured policy reproduces the fixture's realised totals exactly.

use paretobandit::exp::{conditions, run_phases, stream_order, ExpEnv, Phase};
use paretobandit::log::{
    read_log_dir, replay_policy, AdminOp, CaptureMeta, LogWriter, ModelMeta,
    DEFAULT_SEGMENT_BYTES,
};
use paretobandit::router::{
    build_policy, policy_names, BuildCtx, ModelSpec, ParetoRouter, PolicyHost, Prior,
    RouterConfig,
};
use paretobandit::scenario::{run_scenario, RunOptions, ScenarioSpec};
use paretobandit::sim::{EnvView, FlashScenario, Judge, GEMINI_PRO};
use paretobandit::util::rng::Rng;

const D: usize = 6;
const BUDGET: f64 = 6.6e-4;

fn table1() -> Vec<ModelSpec> {
    vec![
        ModelSpec::new("llama-3.1-8b", 0.10, 0.10),
        ModelSpec::new("mistral-large", 0.40, 1.60),
        ModelSpec::new("gemini-2.5-pro", 1.25, 10.0),
    ]
}

fn build(spec: &str, seed: u64) -> PolicyHost {
    let models = table1();
    build_policy(
        spec,
        &BuildCtx {
            d: D,
            budget: Some(BUDGET),
            seed,
            models: &models,
        },
    )
    .unwrap_or_else(|e| panic!("build '{spec}': {e}"))
}

/// Whitened context + bias, the shape the real featurizer produces.
fn ctx(rng: &mut Rng) -> Vec<f64> {
    let mut x: Vec<f64> = (0..D).map(|_| rng.normal()).collect();
    x[D - 1] = 1.0;
    x
}

/// Drive `steps` requests with a seeded environment; returns the arm
/// sequence.  Per-arm reward means make the stream informative so
/// learning policies actually move.
fn drive(host: &mut PolicyHost, steps: usize, env_seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(env_seed);
    let means = [0.55, 0.9, 0.7, 0.8];
    let costs = [2.9e-5, 5.3e-4, 1.5e-2, 2.0e-4];
    let mut arms = Vec::with_capacity(steps);
    for _ in 0..steps {
        let x = ctx(&mut rng);
        let d = host.route(&x);
        arms.push(d.arm);
        let m = means.get(d.arm).copied().unwrap_or(0.5);
        let c = costs.get(d.arm).copied().unwrap_or(1e-4);
        let r = (m + 0.03 * rng.normal()).clamp(0.0, 1.0);
        host.feedback(d.arm, &x, r, c);
    }
    arms
}

#[test]
fn every_policy_routes_only_active_slots_through_churn() {
    for name in policy_names() {
        let mut h = build(name, 7);
        let mut rng = Rng::new(99);
        for i in 0..300usize {
            if i == 100 {
                let slot = h.registry().find("mistral-large").expect("mistral active");
                assert!(h.delete_model(slot));
            }
            if i == 180 {
                let fresh = h.add_model("mistral-large", 0.40, 1.60, None);
                assert_eq!(fresh, 3, "{name}: re-add must land on a fresh slot");
            }
            let x = ctx(&mut rng);
            let d = h.route(&x);
            assert!(
                h.registry().is_active(d.arm),
                "{name}: step {i} picked retired slot {}",
                d.arm
            );
            if (100..180).contains(&i) {
                assert_ne!(d.arm, 1, "{name}: step {i} picked the tombstone");
            }
            h.feedback(d.arm, &x, 0.6, 1e-4);
        }
    }
}

#[test]
fn fixed_and_random_survive_remove_readd_churn() {
    // the pre-v2 baselines indexed raw slot ids and could keep selecting
    // a tombstoned slot after remove_model; eligible-set awareness (plus
    // name re-pinning for Fixed) is the regression under test
    for spec in ["fixed:mistral-large", "random"] {
        let mut h = build(spec, 3);
        let mut rng = Rng::new(4);
        for _ in 0..30 {
            let x = ctx(&mut rng);
            let d = h.route(&x);
            h.feedback(d.arm, &x, 0.7, 1e-4);
        }
        assert!(h.delete_model(1));
        for i in 0..40 {
            let x = ctx(&mut rng);
            let d = h.route(&x);
            assert_ne!(d.arm, 1, "{spec}: picked the tombstone at {i}");
            h.feedback(d.arm, &x, 0.7, 1e-4);
        }
        let fresh = h.add_model("mistral-large", 0.40, 1.60, None);
        assert_eq!(fresh, 3);
        if spec.starts_with("fixed") {
            // the name target re-pins onto the fresh slot
            for _ in 0..20 {
                let x = ctx(&mut rng);
                let d = h.route(&x);
                assert_eq!(d.arm, 3, "{spec}: must follow its model to the new slot");
                h.feedback(d.arm, &x, 0.7, 1e-4);
            }
        } else {
            let mut seen3 = false;
            for _ in 0..60 {
                let x = ctx(&mut rng);
                let d = h.route(&x);
                assert_ne!(d.arm, 1);
                seen3 |= d.arm == 3;
                h.feedback(d.arm, &x, 0.7, 1e-4);
            }
            assert!(seen3, "{spec}: the re-added slot must be eligible again");
        }
    }
}

#[test]
fn every_policy_is_deterministic_under_a_fixed_seed() {
    for name in policy_names() {
        let a = drive(&mut build(name, 5), 250, 11);
        let b = drive(&mut build(name, 5), 250, 11);
        assert_eq!(a, b, "{name}: decisions must replay bit-identically");
    }
}

#[test]
fn every_policy_restores_to_bit_identical_decisions() {
    for name in policy_names() {
        let mut donor = build(name, 5);
        drive(&mut donor, 120, 21);
        let snap = donor.export_state();
        // deliberately different build seed: every learned quantity,
        // RNG stream included, must come from the snapshot
        let mut twin = build(name, 987_654);
        twin.restore_state(&snap)
            .unwrap_or_else(|e| panic!("{name}: restore: {e}"));
        assert_eq!(twin.step(), donor.step(), "{name}: clock must restore");
        let a = drive(&mut donor, 100, 22);
        let b = drive(&mut twin, 100, 22);
        assert_eq!(a, b, "{name}: decisions diverged after restore");
    }
}

// ----------------------------------------------------------------------
// golden bit-identity: hosted trait vs standalone ParetoRouter

/// Raw pre-refactor-style driver: direct `route`/`feedback` calls.
fn raw_pareto(seed: u64) -> ParetoRouter {
    let mut r = ParetoRouter::new(RouterConfig::paretobandit(D, BUDGET, seed));
    for m in table1() {
        r.add_model(&m.name, m.price_in, m.price_out, Prior::Cold);
    }
    r
}

#[test]
fn golden_hosted_pareto_matches_direct_calls_with_admin_churn() {
    let seed = 42;
    let mut hosted = build("paretobandit", seed);
    let mut raw = raw_pareto(seed);
    let mut rng = Rng::new(77);
    let means = [0.55, 0.9, 0.7, 0.8];
    let costs = [2.9e-5, 5.3e-4, 1.5e-2, 2.0e-4];
    for i in 0..800usize {
        match i {
            200 => {
                assert!(hosted.reprice(2, 0.10, 0.10));
                assert!(raw.reprice(2, 0.10, 0.10));
            }
            400 => {
                assert!(hosted.delete_model(1));
                assert!(raw.delete_model(1));
            }
            500 => {
                let h = hosted.add_model("mistral-large", 0.40, 1.60, Some((25.0, 0.7)));
                let r = raw.add_model(
                    "mistral-large",
                    0.40,
                    1.60,
                    Prior::Heuristic { n_eff: 25.0, r0: 0.7 },
                );
                assert_eq!(h, r);
            }
            600 => {
                assert!(hosted.set_budget(3.0e-4));
                assert!(raw.set_budget(3.0e-4));
            }
            _ => {}
        }
        let x = ctx(&mut rng);
        let dh = hosted.route(&x);
        let dr = raw.route(&x);
        assert_eq!(dh.arm, dr.arm, "step {i}: arm diverged");
        assert_eq!(dh.forced, dr.forced, "step {i}: forced flag diverged");
        assert_eq!(
            dh.lambda.to_bits(),
            dr.lambda.to_bits(),
            "step {i}: λ diverged"
        );
        assert_eq!(dh.n_eligible, dr.n_eligible, "step {i}: eligibility diverged");
        let m = means.get(dh.arm).copied().unwrap_or(0.5);
        let c = costs.get(dh.arm).copied().unwrap_or(1e-4);
        let r = (m + 0.03 * rng.normal()).clamp(0.0, 1.0);
        hosted.feedback(dh.arm, &x, r, c);
        raw.feedback(dr.arm, &x, r, c);
    }
}

#[test]
fn golden_exp1_stationary_stream_is_bit_identical() {
    let env = ExpEnv::load(FlashScenario::GoodCheap);
    let seed = 100;
    let view = EnvView::normal(env.world.k());
    let order = stream_order(&env.corpus.test, 9000 + seed);

    // hosted path: the exp harness as it runs post-refactor
    let mut host = conditions::tabula_rasa(&env, 3, Some(BUDGET), seed);
    let log = run_phases(
        &mut host,
        &env.world,
        &env.contexts,
        &env.corpus,
        &[Phase {
            prompts: order.clone(),
            view: &view,
        }],
        Judge::R1,
    );

    // raw path: the pre-refactor loop, direct route/feedback
    let mut raw = ParetoRouter::new(RouterConfig::tabula_rasa(env.d(), Some(BUDGET), seed));
    conditions::register_models(&mut raw, &env.world, 3, None);
    for (t, &pid) in order.iter().enumerate() {
        let p = env.corpus.prompt(pid);
        let x = &env.contexts[pid as usize];
        let d = raw.route(x);
        assert_eq!(d.arm, log[t].arm, "step {t}: arm diverged");
        let r = env.world.reward_view(p, d.arm, &view);
        let c = env.world.cost_view(p, d.arm, &view);
        assert_eq!(r.to_bits(), log[t].reward.to_bits(), "step {t}: reward");
        assert_eq!(c.to_bits(), log[t].cost.to_bits(), "step {t}: cost");
        raw.feedback(d.arm, x, r, c);
        assert_eq!(
            raw.pacer().unwrap().lambda().to_bits(),
            log[t].lambda.to_bits(),
            "step {t}: λ"
        );
    }
}

#[test]
fn golden_exp2_costdrift_timeline_is_bit_identical() {
    let env = ExpEnv::load(FlashScenario::GoodCheap);
    let spec = ScenarioSpec::load_named("exp2_costdrift").expect("exp2 spec");
    let budget = spec.budget.expect("exp2 sets a budget");
    let seed = 123;

    // hosted path: the scenario executor over the v2 hosting layer
    let mut host = conditions::tabula_rasa(&env, 3, Some(budget), seed);
    let opts = RunOptions {
        seed,
        reprice_router: true,
    };
    let run = run_scenario(&spec, &env, &env.world, &mut host, &opts).expect("exp2 run");
    let flat = run.flat();
    assert_eq!(flat.len(), 1824);

    // raw path: replay the identical prompt stream through direct
    // route/feedback with the spec's events applied by hand (the
    // pre-refactor executor semantics)
    const CUT: f64 = 0.017777777777777778;
    let mut raw = ParetoRouter::new(RouterConfig::tabula_rasa(env.d(), Some(budget), seed));
    conditions::register_models(&mut raw, &env.world, 3, None);
    let mut view = EnvView::normal(env.world.k());
    let ws = &env.world.models[GEMINI_PRO];
    for (t, step) in flat.iter().enumerate() {
        if t == 608 {
            view.price_mult[GEMINI_PRO] = CUT;
            raw.reprice(GEMINI_PRO, ws.price_in_per_m * CUT, ws.price_out_per_m * CUT);
        }
        if t == 1216 {
            view.price_mult[GEMINI_PRO] = 1.0;
            raw.reprice(GEMINI_PRO, ws.price_in_per_m, ws.price_out_per_m);
        }
        let p = env.corpus.prompt(step.prompt);
        let x = &env.contexts[step.prompt as usize];
        let d = raw.route(x);
        assert_eq!(d.arm, step.arm, "step {t}: arm diverged");
        let r = env.world.reward_view(p, d.arm, &view);
        let c = env.world.cost_view(p, d.arm, &view);
        assert_eq!(r.to_bits(), step.reward.to_bits(), "step {t}: reward");
        assert_eq!(c.to_bits(), step.cost.to_bits(), "step {t}: cost");
        raw.feedback(d.arm, x, r, c);
        assert_eq!(
            raw.pacer().unwrap().lambda().to_bits(),
            step.lambda.to_bits(),
            "step {t}: λ"
        );
    }
}

// ----------------------------------------------------------------------
// replay-based goldens over a deterministic fixture capture

const CAP_SEED: u64 = 42;
const CAP_POLICY: &str = "paretobandit";
const CAP_STEPS: u64 = 240;

/// Realised totals of the fixture capture, for golden comparison.
struct CaptureTotals {
    decisions: u64,
    reward_sum: f64,
    cost_sum: f64,
    /// final dual λ of the capturing host (bits)
    lambda_bits: u64,
}

/// Write the fixture capture: a single-shard cold capture of the
/// `paretobandit` policy over the Part-1 reward schedule, with admin
/// churn (runtime onboarding, a reprice, a budget change) logged
/// mid-stream — each record appended exactly the way the serving path
/// logs it (decision after route, feedback after apply, admin after
/// success, `queued=false` on the single-worker path).
fn capture_fixture(dir: &std::path::Path) -> CaptureTotals {
    let models = table1();
    let mut host = build(CAP_POLICY, CAP_SEED);
    let meta = CaptureMeta {
        shard: 0,
        d: D as u32,
        seed: CAP_SEED,
        budget: Some(BUDGET),
        policy: CAP_POLICY.to_string(),
        warm: false,
        models: models
            .iter()
            .map(|m| {
                Some(ModelMeta {
                    name: m.name.clone(),
                    price_in: m.price_in,
                    price_out: m.price_out,
                    prior: m.prior,
                })
            })
            .collect(),
    };
    let mut w = LogWriter::create(dir, meta, DEFAULT_SEGMENT_BYTES).expect("fixture writer");
    let mut rng = Rng::new(314);
    let means = [0.55, 0.9, 0.7, 0.8];
    let costs = [2.9e-5, 5.3e-4, 1.5e-2, 2.0e-4];
    let mut totals = CaptureTotals {
        decisions: 0,
        reward_sum: 0.0,
        cost_sum: 0.0,
        lambda_bits: 0,
    };
    for i in 0..CAP_STEPS {
        if i == 80 {
            let slot = host.add_model("flash", 0.3, 2.5, Some((20.0, 0.5)));
            assert_eq!(slot, 3, "fixture: onboarded model lands on slot 3");
            w.append_admin(&AdminOp::AddModel {
                name: "flash".to_string(),
                price_in: 0.3,
                price_out: 2.5,
                prior: Some((20.0, 0.5)),
            })
            .unwrap();
        }
        if i == 160 {
            assert!(host.reprice(2, 0.6, 5.0));
            w.append_admin(&AdminOp::Reprice {
                slot: 2,
                price_in: 0.6,
                price_out: 5.0,
            })
            .unwrap();
            assert!(host.set_budget(BUDGET * 1.5));
            w.append_admin(&AdminOp::SetBudget {
                budget: BUDGET * 1.5,
            })
            .unwrap();
        }
        let x = ctx(&mut rng);
        let d = host.route(&x);
        w.append_decision(
            host.step(),
            i,
            d.lambda,
            d.arm as u32,
            d.forced,
            d.n_eligible as u32,
            &x,
            host.last_eligible(),
            host.blended_prices(),
            host.c_tilde_prices(),
        )
        .unwrap();
        let m = means.get(d.arm).copied().unwrap_or(0.5);
        let c = costs.get(d.arm).copied().unwrap_or(1e-4);
        let r = (m + 0.03 * rng.normal()).clamp(0.0, 1.0);
        host.feedback(d.arm, &x, r, c);
        w.append_feedback(i, d.arm as u32, r, c, false).unwrap();
        totals.decisions += 1;
        totals.reward_sum += r;
        totals.cost_sum += c;
    }
    w.flush().unwrap();
    totals.lambda_bits = host.lambda().to_bits();
    totals
}

fn fixture_dir(name: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("pb_conf_replay_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn replay_golden_captured_policy_reproduces_the_fixture_exactly() {
    let dir = fixture_dir("golden");
    let totals = capture_fixture(&dir);
    let log = read_log_dir(&dir).unwrap();
    assert!(!log.damaged());

    let rep = replay_policy(&log, CAP_POLICY).unwrap();
    assert_eq!(rep.decisions, totals.decisions);
    assert_eq!(rep.scored, totals.decisions);
    assert_eq!(
        rep.diverged, 0,
        "captured policy must replay bit-identically: {:?}",
        rep.divergences
    );
    assert_eq!(rep.matched, rep.scored);
    assert_eq!(rep.lambda_drift, 0, "λ trajectory must reproduce exactly");
    assert!(!rep.hit_restore);
    // single shard, same stream order, raw-bit storage: the realised
    // totals reproduce to the last bit, not approximately
    assert_eq!(rep.reward_matched.to_bits(), totals.reward_sum.to_bits());
    assert_eq!(rep.est_spend.to_bits(), totals.cost_sum.to_bits());
    assert_eq!(rep.lambda.to_bits(), totals.lambda_bits);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn replay_golden_every_policy_summary_is_stable() {
    let dir = fixture_dir("all");
    let totals = capture_fixture(&dir);
    let log = read_log_dir(&dir).unwrap();

    for name in policy_names() {
        let a = replay_policy(&log, name).unwrap_or_else(|e| panic!("{name}: replay: {e}"));
        let b = replay_policy(&log, name).unwrap_or_else(|e| panic!("{name}: replay: {e}"));
        // the summary document is the golden artifact: two independent
        // replays must serialize byte-identically
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "{name}: replay summary is not stable"
        );
        assert_eq!(a.decisions, totals.decisions, "{name}: decision count");
        assert_eq!(a.scored, totals.decisions, "{name}: scored count");
        assert!(a.matched <= a.scored, "{name}: matched bound");
        assert!(
            a.est_spend.is_finite() && a.est_spend >= 0.0,
            "{name}: est_spend must be a finite non-negative total"
        );
        assert!(
            a.reward_matched.is_finite(),
            "{name}: reward total must be finite"
        );
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// Regenerates `tests/fixtures/replay/` (the on-disk capture plus the
/// per-policy summary lines) after a deliberate codec or policy change:
/// `cargo test -q --test policy_conformance -- --ignored`.
#[test]
#[ignore = "writes tests/fixtures/replay; run explicitly after a format change"]
fn regen_replay_fixture() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/replay");
    let cap = root.join("capture");
    let _ = std::fs::remove_dir_all(&cap);
    capture_fixture(&cap);
    let log = read_log_dir(&cap).unwrap();
    let mut lines = Vec::new();
    for name in policy_names() {
        let rep = replay_policy(&log, name).unwrap();
        lines.push(rep.to_json().to_string());
    }
    std::fs::create_dir_all(&root).unwrap();
    let mut doc = lines.join("\n");
    doc.push('\n');
    std::fs::write(root.join("summaries.jsonl"), doc).unwrap();
}
