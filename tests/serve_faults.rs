//! Fault injection against the event-loop serving path: slow writers,
//! half-open and mid-frame-disconnected connections, oversized and
//! garbage frames, connection floods, deep pipelining and saturated
//! shards.  The invariant under every fault is the same — *other
//! connections keep progressing, the victim gets a typed error, nothing
//! stalls and nothing panics*.  The saturation scenario also runs against
//! the threaded oracle, which proves the `Dispatch::forward` timeout path
//! (a wedged shard must answer `shard_timeout`, not hang the connection
//! thread forever).
//!
//! The stall lever: prompts shaped `STALL:<ms> ...` make the test
//! featurizer sleep inside the shard worker, which is exactly where a
//! slow embedding model would wedge a real deployment.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use paretobandit::client::{ClientError, ParetoClient};
use paretobandit::pacer::{PacerConfig, SharedPacer};
use paretobandit::router::{ContextCache, ParetoRouter, Prior, RouterConfig};
use paretobandit::server::{EngineConfig, EventEngine, Metrics, ServerState, ShardedEngine};
use paretobandit::sim::hash_features;
use paretobandit::util::json::Json;

const D: usize = 8;
const BUDGET: f64 = 4e-4;

fn builder() -> impl Fn(usize) -> ServerState + Send + Sync + 'static {
    let ledger = Arc::new(SharedPacer::new(PacerConfig::new(BUDGET)));
    move |shard: usize| {
        let mut router =
            ParetoRouter::new(RouterConfig::tabula_rasa(D, Some(BUDGET), 700 + shard as u64));
        router.use_shared_pacer(ledger.clone());
        router.add_model("llama", 0.10, 0.10, Prior::Cold);
        router.add_model("mistral", 0.40, 1.60, Prior::Cold);
        ServerState::new(
            router,
            ContextCache::new(65536),
            Box::new(|t: &str| {
                if let Some(rest) = t.strip_prefix("STALL:") {
                    let ms: u64 = rest
                        .split_whitespace()
                        .next()
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(300);
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Ok(hash_features(t, D))
            }),
            Arc::new(Metrics::new()),
        )
    }
}

fn spawn_event(cfg: EngineConfig) -> EventEngine {
    EventEngine::spawn("127.0.0.1:0", cfg, builder()).unwrap()
}

fn raw(addr: &SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    s
}

fn route_line(id: u64, prompt: &str) -> String {
    format!(r#"{{"v":2,"op":"route","id":{id},"prompt":"{prompt}"}}"#) + "\n"
}

/// Read one response line and parse it; panics on EOF.
fn read_resp(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    let n = reader.read_line(&mut line).unwrap();
    assert!(n > 0, "server closed the connection unexpectedly");
    Json::parse(&line).unwrap()
}

fn code_of(resp: &Json) -> String {
    resp.get("code")
        .and_then(Json::as_str)
        .unwrap_or_default()
        .to_string()
}

#[test]
fn slow_writer_does_not_stall_other_connections() {
    let engine = spawn_event(EngineConfig::new(2).merge_every(Duration::from_secs(3600)));
    let addr = engine.addr;

    // slowloris: one byte every 5 ms — the frame takes ~250 ms to arrive
    let slow = std::thread::spawn(move || {
        let mut s = raw(&addr);
        let frame = route_line(9999, "slow but honest");
        for b in frame.as_bytes() {
            s.write_all(std::slice::from_ref(b)).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(5));
        }
        let mut r = BufReader::new(s.try_clone().unwrap());
        let resp = read_resp(&mut r);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("id").and_then(Json::as_f64), Some(9999.0));
    });

    // meanwhile a normal client must complete a full route+feedback run
    let mut c = ParetoClient::connect(addr).unwrap();
    let t0 = Instant::now();
    for i in 0..60u64 {
        c.route(i, &format!("fast client {i}")).unwrap();
        c.feedback(i, 0.8, 1e-4).unwrap();
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "fast client starved behind a slow writer"
    );
    slow.join().unwrap();
    engine.stop();
}

#[test]
fn mid_frame_disconnects_and_churn_leave_service_intact() {
    let engine = spawn_event(EngineConfig::new(2).merge_every(Duration::from_secs(3600)));
    let addr = engine.addr;

    // connections that die mid-frame
    for i in 0..20 {
        let mut s = raw(&addr);
        let _ = s.write_all(format!(r#"{{"v":2,"op":"route","id":{i},"pro"#).as_bytes());
        drop(s);
    }
    // connect/disconnect churn with no data at all
    for _ in 0..30 {
        drop(raw(&addr));
    }
    // half-open idlers that stay connected but silent for the whole test
    let idlers: Vec<TcpStream> = (0..5).map(|_| raw(&addr)).collect();

    let mut c = ParetoClient::connect(addr).unwrap();
    for i in 0..40u64 {
        let r = c.route(1000 + i, &format!("survivor {i}")).unwrap();
        assert_eq!(r.id, 1000 + i);
    }
    drop(idlers);
    engine.stop(); // must join cleanly despite the churn above
}

#[test]
fn oversized_unterminated_frame_gets_typed_error_then_close() {
    let engine = spawn_event(
        EngineConfig::new(1)
            .merge_every(Duration::from_secs(3600))
            .max_frame(1024),
    );
    let mut s = raw(&engine.addr);
    // 4 KiB with no newline: the frame can never complete within
    // max_frame, so the server answers bad_request and closes
    s.write_all(&vec![b'a'; 4096]).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let resp = read_resp(&mut r);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(code_of(&resp), "bad_request");
    // ... and then EOF
    let mut rest = String::new();
    assert_eq!(r.read_line(&mut rest).unwrap(), 0, "expected close after oversize");
    engine.stop();
}

#[test]
fn oversized_terminated_frame_errors_but_connection_survives() {
    let engine = spawn_event(
        EngineConfig::new(1)
            .merge_every(Duration::from_secs(3600))
            .max_frame(1024),
    );
    let mut s = raw(&engine.addr);
    let mut big = vec![b'b'; 2048];
    big.push(b'\n');
    s.write_all(&big).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let resp = read_resp(&mut r);
    assert_eq!(code_of(&resp), "bad_request");
    // the frame boundary was still parseable, so the connection lives
    s.write_all(route_line(7, "after the flood").as_bytes()).unwrap();
    let resp = read_resp(&mut r);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(resp.get("id").and_then(Json::as_f64), Some(7.0));
    engine.stop();
}

#[test]
fn garbage_frames_get_typed_errors_and_service_continues() {
    let engine = spawn_event(EngineConfig::new(1).merge_every(Duration::from_secs(3600)));
    let mut s = raw(&engine.addr);
    let mut r = BufReader::new(s.try_clone().unwrap());

    s.write_all(b"this is not json\n").unwrap();
    assert_eq!(code_of(&read_resp(&mut r)), "bad_request");
    s.write_all(b"\"a bare string\"\n").unwrap();
    assert_eq!(code_of(&read_resp(&mut r)), "bad_request");
    s.write_all(b"{\"op\":\"no_such_verb\"}\n").unwrap();
    assert_eq!(code_of(&read_resp(&mut r)), "bad_request");
    // invalid UTF-8 inside a frame
    s.write_all(&[0xff, 0xfe, 0xfd, b'\n']).unwrap();
    assert_eq!(code_of(&read_resp(&mut r)), "bad_request");

    s.write_all(route_line(1, "still here").as_bytes()).unwrap();
    let resp = read_resp(&mut r);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    engine.stop();
}

#[test]
fn connection_flood_is_shed_with_typed_unavailable() {
    let engine = spawn_event(
        EngineConfig::new(1)
            .merge_every(Duration::from_secs(3600))
            .max_conns(8),
    );
    let addr = engine.addr;

    // fill every slot, proving each connection is actually admitted
    let mut residents = Vec::new();
    for i in 0..8u64 {
        let mut c = ParetoClient::connect(addr).unwrap();
        c.route(i, "resident").unwrap();
        residents.push(c);
    }
    // the 9th is turned away with a typed line (or a straight close if
    // the reject write raced the socket buffer)
    let s = raw(&addr);
    let mut r = BufReader::new(s);
    let mut line = String::new();
    let n = r.read_line(&mut line).unwrap();
    if n > 0 {
        let resp = Json::parse(&line).unwrap();
        assert_eq!(code_of(&resp), "unavailable");
    }
    // residents are unaffected by the shed
    for (i, c) in residents.iter_mut().enumerate() {
        c.route(100 + i as u64, "still resident").unwrap();
    }
    // freeing slots re-opens the door
    drop(residents);
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let mut c = ParetoClient::connect(addr).unwrap();
        match c.route(500, "late arrival") {
            Ok(_) => break,
            Err(_) => {
                assert!(Instant::now() < deadline, "slots never freed after flood");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    engine.stop();
}

#[test]
fn pipelined_requests_complete_out_of_order_matched_by_id() {
    // two shards: the first request stalls shard 0 for 600 ms, the second
    // sails through shard 1 — its response must arrive first, and the id
    // echo is what lets the client pair them up
    let engine = spawn_event(EngineConfig::new(2).merge_every(Duration::from_secs(3600)));
    let mut s = raw(&engine.addr);
    let mut r = BufReader::new(s.try_clone().unwrap());

    let mut burst = String::new();
    burst.push_str(&route_line(1, "STALL:600 heavy"));
    burst.push_str(&route_line(2, "light"));
    s.write_all(burst.as_bytes()).unwrap();

    let first = read_resp(&mut r);
    let second = read_resp(&mut r);
    assert_eq!(first.get("id").and_then(Json::as_f64), Some(2.0), "light request should finish first");
    assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(second.get("id").and_then(Json::as_f64), Some(1.0));
    assert_eq!(second.get("ok").and_then(Json::as_bool), Some(true));
    engine.stop();
}

#[test]
fn saturated_shard_sheds_and_times_out_typed_on_the_event_loop() {
    let engine = spawn_event(
        EngineConfig::new(1)
            .merge_every(Duration::from_secs(3600))
            .shard_timeout(Duration::from_millis(250))
            .shard_queue_cap(3),
    );
    let mut s = raw(&engine.addr);
    let mut r = BufReader::new(s.try_clone().unwrap());

    // one wedge + 7 followers in a single burst: 2 more fit under the
    // queue cap (typed shard_timeout at the deadline), the rest are shed
    // immediately (typed unavailable)
    let mut burst = String::new();
    burst.push_str(&route_line(1, "STALL:1200 wedge"));
    for id in 2..=8u64 {
        burst.push_str(&route_line(id, "follower"));
    }
    let t0 = Instant::now();
    s.write_all(burst.as_bytes()).unwrap();

    let mut timeouts = 0;
    let mut shed = 0;
    for _ in 0..8 {
        let resp = read_resp(&mut r);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        match code_of(&resp).as_str() {
            "shard_timeout" => timeouts += 1,
            "unavailable" => shed += 1,
            other => panic!("unexpected code under saturation: {other}"),
        }
    }
    // every response arrived long before the 1.2 s wedge cleared — the
    // reactor answered from deadlines and shedding, not from the shard
    assert!(
        t0.elapsed() < Duration::from_millis(1100),
        "saturation answers took {:?} — the loop waited on the wedged shard",
        t0.elapsed()
    );
    assert_eq!(timeouts, 3, "wedge + 2 queued followers time out");
    assert_eq!(shed, 5, "followers beyond the queue cap are shed");

    // once the wedge clears and late completions drain the zombie load,
    // the same connection serves again
    std::thread::sleep(Duration::from_millis(1300));
    s.write_all(route_line(100, "recovered").as_bytes()).unwrap();
    let resp = read_resp(&mut r);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "no recovery after wedge: {resp:?}");
    engine.stop();
}

#[test]
fn saturated_shard_times_out_typed_on_the_threaded_oracle() {
    // the regression this pins down: Dispatch::forward used a blocking
    // rx.recv(), so a wedged shard hung the connection thread forever;
    // it must instead answer a typed shard_timeout within the deadline
    let cfg = EngineConfig::new(1)
        .merge_every(Duration::from_secs(3600))
        .shard_timeout(Duration::from_millis(250));
    let engine = ShardedEngine::spawn("127.0.0.1:0", cfg, builder()).unwrap();
    let mut c = ParetoClient::connect(engine.addr).unwrap();

    let t0 = Instant::now();
    let r1 = c.route(1, "STALL:1200 wedge");
    let r2 = c.route(2, "follower");
    let elapsed = t0.elapsed();
    for (label, r) in [("wedge", r1), ("follower", r2)] {
        match r {
            Err(ClientError::Api(e)) => assert_eq!(
                e.code.as_str(),
                "shard_timeout",
                "{label}: wrong code: {e}"
            ),
            other => panic!("{label}: expected typed shard_timeout, got {other:?}"),
        }
    }
    assert!(
        elapsed < Duration::from_millis(1100),
        "threaded path blocked on a wedged shard for {elapsed:?}"
    );

    // after the wedge clears the engine serves normally again
    std::thread::sleep(Duration::from_millis(1300));
    let r = c.route(3, "recovered").unwrap();
    assert_eq!(r.id, 3);
    engine.stop();
}
