//! Event-loop vs threaded engine conformance: the reactor path
//! (`EventEngine`) must be *bit-identical* to the thread-per-connection
//! oracle (`ShardedEngine`) — same arm choices, same λ trajectory (to the
//! last mantissa bit), same error codes, same metrics counters — on an
//! identical randomized workload of interleaved `route` / `route_batch` /
//! `feedback` / `feedback_batch` / admin verbs over 4 shards.  The two
//! engines share the shard-worker and merger code (`spawn_shards` /
//! `spawn_merger`); this suite proves the reactor's dispatch mirror
//! (round-robin tickets, owner-table claim/peek, sub-batch fan-out)
//! introduces no drift.
//!
//! Determinism preconditions baked into the harness: one sequential
//! client (so the ticket sequence is the arrival order), timer merges
//! disabled (only client-driven `sync` cycles run), and rewards/costs
//! fixed by the script rather than derived from wall-clock anything.
//!
//! Override the op count with PB_CONF_OPS on slow hardware.

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use paretobandit::client::ParetoClient;
use paretobandit::pacer::{PacerConfig, SharedPacer};
use paretobandit::router::{ContextCache, ModelRef, ParetoRouter, Prior, RouterConfig};
use paretobandit::server::{
    EngineConfig, EventEngine, Metrics, ServerState, ShardedEngine,
};
use paretobandit::sim::hash_features;
use paretobandit::util::env_or;
use paretobandit::util::rng::Rng;

const D: usize = 8;
const BUDGET: f64 = 4e-4;
const WORKERS: usize = 4;

/// Both engines get byte-identical per-shard builders: same seeds, same
/// portfolio, same featurizer, and a fresh (but identically configured)
/// shared dollar ledger per engine.
fn builder(ledger: Arc<SharedPacer>) -> impl Fn(usize) -> ServerState + Send + Sync + 'static {
    move |shard: usize| {
        let mut router =
            ParetoRouter::new(RouterConfig::tabula_rasa(D, Some(BUDGET), 900 + shard as u64));
        router.use_shared_pacer(ledger.clone());
        router.add_model("llama", 0.10, 0.10, Prior::Cold);
        router.add_model("mistral", 0.40, 1.60, Prior::Cold);
        ServerState::new(
            router,
            ContextCache::new(65536),
            Box::new(|t: &str| Ok(hash_features(t, D))),
            Arc::new(Metrics::new()),
        )
    }
}

/// Timer merges off: only `sync` verbs trigger cycles, so both engines
/// merge at exactly the same points in the request stream.
fn cfg() -> EngineConfig {
    EngineConfig::new(WORKERS).merge_every(Duration::from_secs(3600))
}

enum AnyEngine {
    Event(EventEngine),
    Threaded(ShardedEngine),
}

impl AnyEngine {
    fn spawn(event: bool) -> AnyEngine {
        let ledger = Arc::new(SharedPacer::new(PacerConfig::new(BUDGET)));
        if event {
            AnyEngine::Event(EventEngine::spawn("127.0.0.1:0", cfg(), builder(ledger)).unwrap())
        } else {
            AnyEngine::Threaded(
                ShardedEngine::spawn("127.0.0.1:0", cfg(), builder(ledger)).unwrap(),
            )
        }
    }

    fn addr(&self) -> SocketAddr {
        match self {
            AnyEngine::Event(e) => e.addr,
            AnyEngine::Threaded(e) => e.addr,
        }
    }

    fn stop(self) {
        match self {
            AnyEngine::Event(e) => e.stop(),
            AnyEngine::Threaded(e) => e.stop(),
        }
    }
}

/// One step of the scripted workload.  The script is *data* — generated
/// once from a seed, then replayed verbatim against both engines.
enum Op {
    Route(u64, String),
    RouteBatch(Vec<(u64, String)>),
    Feedback(u64, f64, f64),
    FeedbackBatch(Vec<(u64, f64, f64)>),
    /// feedback on an id that was already claimed — must answer
    /// `unknown_id` on both paths
    DoubleFeedback(u64),
    AddModel(String, f64, f64),
    Reprice(f64, f64),
    SetBudget(f64),
    Sync,
}

/// Generate a deterministic interleaving.  Feedback targets are drawn
/// from ids the script itself routed earlier, so the owner table sees the
/// same claim/peek sequence on both engines.
fn make_script(n_ops: usize, seed: u64) -> Vec<Op> {
    let mut rng = Rng::new(seed);
    let mut next_id = 0u64;
    let mut open: Vec<u64> = Vec::new();
    let mut closed: Vec<u64> = Vec::new();
    let mut added = 0usize;
    let mut ops = Vec::new();
    for _ in 0..n_ops {
        let roll = rng.below(100);
        if roll < 30 {
            let id = next_id;
            next_id += 1;
            open.push(id);
            ops.push(Op::Route(id, format!("prompt {id} salt {}", rng.below(9973))));
        } else if roll < 50 {
            let k = rng.range(1, 9);
            let mut items = Vec::new();
            for _ in 0..k {
                let id = next_id;
                next_id += 1;
                open.push(id);
                items.push((id, format!("batch prompt {id} salt {}", rng.below(9973))));
            }
            ops.push(Op::RouteBatch(items));
        } else if roll < 70 && !open.is_empty() {
            let id = open.swap_remove(rng.below(open.len()));
            closed.push(id);
            ops.push(Op::Feedback(
                id,
                0.3 + 0.6 * rng.f64(),
                1e-4 + 6e-4 * rng.f64(),
            ));
        } else if roll < 85 && open.len() >= 2 {
            let k = rng.range(2, 6.min(open.len()));
            let mut items = Vec::new();
            for _ in 0..k {
                let id = open.swap_remove(rng.below(open.len()));
                closed.push(id);
                items.push((id, 0.3 + 0.6 * rng.f64(), 1e-4 + 6e-4 * rng.f64()));
            }
            ops.push(Op::FeedbackBatch(items));
        } else if roll < 88 && !closed.is_empty() {
            ops.push(Op::DoubleFeedback(closed[rng.below(closed.len())]));
        } else if roll < 91 {
            added += 1;
            ops.push(Op::AddModel(
                format!("hotswap-{added}"),
                0.2 + 0.1 * (added as f64),
                0.8,
            ));
        } else if roll < 94 {
            ops.push(Op::Reprice(0.3 + 0.2 * rng.f64(), 1.2 + 0.4 * rng.f64()));
        } else if roll < 96 {
            ops.push(Op::SetBudget(3e-4 + 4e-4 * rng.f64()));
        } else {
            ops.push(Op::Sync);
        }
    }
    ops
}

/// Everything observable that must match, flattened to strings so an
/// assert failure prints a readable diff position.  λ is compared via
/// `f64::to_bits` — bit-identical, not approximately equal.
fn run_script(addr: SocketAddr, ops: &[Op]) -> Vec<String> {
    let mut c = ParetoClient::connect(addr).unwrap();
    let mut t = Vec::new();
    for op in ops {
        match op {
            Op::Route(id, prompt) => match c.route(*id, prompt) {
                Ok(r) => t.push(format!(
                    "route {}:{}:{}:{:016x}:{}:{}",
                    r.id,
                    r.arm,
                    r.model,
                    r.lambda.to_bits(),
                    r.forced,
                    r.shard
                )),
                Err(e) => t.push(format!("route err {e}")),
            },
            Op::RouteBatch(items) => match c.route_batch(items) {
                Ok(rs) => {
                    for r in rs {
                        match r {
                            Ok(r) => t.push(format!(
                                "rb {}:{}:{}:{:016x}:{}:{}",
                                r.id,
                                r.arm,
                                r.model,
                                r.lambda.to_bits(),
                                r.forced,
                                r.shard
                            )),
                            Err(e) => t.push(format!("rb err {}", e.code.as_str())),
                        }
                    }
                }
                Err(e) => t.push(format!("rb transport {e}")),
            },
            Op::Feedback(id, reward, cost) => match c.feedback(*id, *reward, *cost) {
                Ok(arm) => t.push(format!("fb {id}:{arm}")),
                Err(e) => t.push(format!("fb err {e}")),
            },
            Op::FeedbackBatch(items) => match c.feedback_batch(items) {
                Ok(rs) => {
                    for (i, r) in rs.iter().enumerate() {
                        match r {
                            Ok(arm) => t.push(format!("fbb {i}:{arm}")),
                            Err(e) => t.push(format!("fbb {i} err {}", e.code.as_str())),
                        }
                    }
                }
                Err(e) => t.push(format!("fbb transport {e}")),
            },
            Op::DoubleFeedback(id) => match c.feedback(*id, 0.5, 1e-4) {
                Ok(arm) => t.push(format!("dupfb UNEXPECTED_OK {id}:{arm}")),
                Err(paretobandit::client::ClientError::Api(e)) => {
                    t.push(format!("dupfb {}", e.code.as_str()))
                }
                Err(e) => t.push(format!("dupfb transport {e}")),
            },
            Op::AddModel(name, pi, po) => match c.add_model(name, *pi, *po, Some((25.0, 0.7))) {
                Ok(arm) => t.push(format!("add {name}:{arm}")),
                Err(e) => t.push(format!("add err {e}")),
            },
            Op::Reprice(pi, po) => {
                match c.reprice(&ModelRef::Name("mistral".into()), *pi, *po) {
                    Ok(arm) => t.push(format!("reprice {arm}")),
                    Err(e) => t.push(format!("reprice err {e}")),
                }
            }
            Op::SetBudget(b) => match c.set_budget(*b) {
                Ok(nb) => t.push(format!("budget {:016x}", nb.to_bits())),
                Err(e) => t.push(format!("budget err {e}")),
            },
            Op::Sync => match c.sync() {
                Ok(s) => t.push(format!("sync {}:{}", s.synced_shards, s.merges)),
                Err(e) => t.push(format!("sync err {e}")),
            },
        }
    }
    // closing sync pins every shard to the merged posterior, then the
    // deterministic slice of the metrics registry seals the transcript
    let s = c.sync().unwrap();
    t.push(format!("final-sync {}:{}", s.synced_shards, s.merges));
    let m = c.metrics().unwrap();
    for key in [
        "requests",
        "feedbacks",
        "errors",
        "total_spend",
        "mean_cost",
        "lambda",
        "policy",
        "workers",
        "per_shard",
        "per_arm",
    ] {
        t.push(format!(
            "metric {key}={}",
            m.get(key).map(|v| v.to_string()).unwrap_or_default()
        ));
    }
    t
}

#[test]
fn event_loop_decisions_are_bit_identical_to_the_threaded_oracle() {
    let n_ops: usize = env_or("PB_CONF_OPS", 400);
    for seed in [11u64, 12, 13] {
        let ops = make_script(n_ops, seed);
        let ev = AnyEngine::spawn(true);
        let ev_t = run_script(ev.addr(), &ops);
        ev.stop();
        let th = AnyEngine::spawn(false);
        let th_t = run_script(th.addr(), &ops);
        th.stop();
        assert_eq!(
            ev_t.len(),
            th_t.len(),
            "seed {seed}: transcript lengths diverge ({} vs {})",
            ev_t.len(),
            th_t.len()
        );
        for (i, (a, b)) in ev_t.iter().zip(th_t.iter()).enumerate() {
            assert_eq!(a, b, "seed {seed}: transcripts diverge at step {i}");
        }
    }
}

#[test]
fn admin_error_codes_match_across_engines() {
    // the typed failure paths must agree too: duplicate model names,
    // unknown model refs, feedback on never-routed ids
    fn probe(addr: SocketAddr) -> Vec<String> {
        let mut c = ParetoClient::connect(addr).unwrap();
        let mut t = Vec::new();
        let code = |e: paretobandit::client::ClientError| match e {
            paretobandit::client::ClientError::Api(a) => a.code.as_str().to_string(),
            paretobandit::client::ClientError::Transport(m) => format!("transport:{m}"),
        };
        t.push(match c.add_model("llama", 0.1, 0.1, None) {
            Ok(_) => "dup-add ok".into(),
            Err(e) => format!("dup-add {}", code(e)),
        });
        t.push(match c.delete_model(&ModelRef::Name("nosuch".into())) {
            Ok(_) => "del ok".into(),
            Err(e) => format!("del {}", code(e)),
        });
        t.push(match c.feedback(u64::MAX, 0.5, 1e-4) {
            Ok(_) => "orphan-fb ok".into(),
            Err(e) => format!("orphan-fb {}", code(e)),
        });
        t.push(match c.reprice(&ModelRef::Arm(77), 0.1, 0.1) {
            Ok(_) => "reprice ok".into(),
            Err(e) => format!("reprice {}", code(e)),
        });
        t
    }
    let ev = AnyEngine::spawn(true);
    let ev_t = probe(ev.addr());
    ev.stop();
    let th = AnyEngine::spawn(false);
    let th_t = probe(th.addr());
    th.stop();
    assert_eq!(ev_t, th_t, "admin error transcripts diverge");
}
