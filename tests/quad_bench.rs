//! Ignored A/B probe comparing `Mat::quad_form` (now the symmetric
//! upper-triangle sweep, vectorised via `linalg::dot`) against a scalar
//! reference of the same algorithm — run with `--ignored` to see what
//! the unrolled-dot vectorisation buys on this machine.

use paretobandit::linalg::Mat;
use paretobandit::util::bench::{bench_batched, black_box};
use paretobandit::util::prop;
use paretobandit::util::rng::Rng;

fn quad_sym_scalar(m: &Mat, x: &[f64]) -> f64 {
    // same symmetric sweep as Mat::quad_form, scalar inner loop
    let d = m.dim();
    let mut diag = 0.0;
    let mut off = 0.0;
    for i in 0..d {
        let row = m.row(i);
        diag += x[i] * x[i] * row[i];
        let mut s = 0.0;
        for j in (i + 1)..d {
            s += row[j] * x[j];
        }
        off += x[i] * s;
    }
    diag + 2.0 * off
}

#[test]
#[ignore]
fn quad_form_variants() {
    let mut rng = Rng::new(1);
    for d in [26usize, 385] {
        let m = Mat::from_rows(d, prop::spd(&mut rng, d, 1.0));
        let xs: Vec<Vec<f64>> = (0..64).map(|_| prop::vec_f64(&mut rng, d, 1.0)).collect();
        let mut i = 0;
        let full = bench_batched(100, 200, 64, || {
            black_box(m.quad_form(&xs[i & 63]));
            i += 1;
        });
        let mut j = 0;
        let half = bench_batched(100, 200, 64, || {
            black_box(quad_sym_scalar(&m, &xs[j & 63]));
            j += 1;
        });
        // correctness
        for x in &xs[..8] {
            assert!((m.quad_form(x) - quad_sym_scalar(&m, x)).abs() < 1e-9 * d as f64);
        }
        println!("d={d}: quad_form(vectorised) {:.0} ns | scalar reference {:.0} ns ({:+.0}%)",
            full.mean_ns, half.mean_ns, (half.mean_ns/full.mean_ns - 1.0)*100.0);
    }
}
