use paretobandit::linalg::Mat;
use paretobandit::util::bench::{bench_batched, black_box};
use paretobandit::util::prop;
use paretobandit::util::rng::Rng;

fn quad_sym(m: &Mat, x: &[f64]) -> f64 {
    // exploit symmetry: sum_i x_i^2 a_ii + 2 sum_{i<j} x_i x_j a_ij
    let d = m.dim();
    let mut diag = 0.0;
    let mut off = 0.0;
    for i in 0..d {
        let row = m.row(i);
        diag += x[i] * x[i] * row[i];
        let mut s = 0.0;
        for j in (i + 1)..d {
            s += row[j] * x[j];
        }
        off += x[i] * s;
    }
    diag + 2.0 * off
}

#[test]
#[ignore]
fn quad_form_variants() {
    let mut rng = Rng::new(1);
    for d in [26usize, 385] {
        let m = Mat::from_rows(d, prop::spd(&mut rng, d, 1.0));
        let xs: Vec<Vec<f64>> = (0..64).map(|_| prop::vec_f64(&mut rng, d, 1.0)).collect();
        let mut i = 0;
        let full = bench_batched(100, 200, 64, || {
            black_box(m.quad_form(&xs[i & 63]));
            i += 1;
        });
        let mut j = 0;
        let half = bench_batched(100, 200, 64, || {
            black_box(quad_sym(&m, &xs[j & 63]));
            j += 1;
        });
        // correctness
        for x in &xs[..8] {
            assert!((m.quad_form(x) - quad_sym(&m, x)).abs() < 1e-9 * d as f64);
        }
        println!("d={d}: full {:.0} ns | symmetric-half {:.0} ns ({:+.0}%)",
            full.mean_ns, half.mean_ns, (half.mean_ns/full.mean_ns - 1.0)*100.0);
    }
}
