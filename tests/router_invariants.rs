//! Coordinator invariants + failure injection (integration level):
//! randomized property sweeps over routing, budget state, hot-swap and
//! feedback-path behaviour.

use paretobandit::router::{ContextCache, ParetoRouter, Pending, Prior, RouterConfig};
use paretobandit::util::prop;
use paretobandit::util::rng::Rng;

const D: usize = 10;

fn ctx(rng: &mut Rng) -> Vec<f64> {
    let mut x: Vec<f64> = (0..D).map(|_| rng.normal()).collect();
    x[D - 1] = 1.0;
    x
}

fn random_portfolio(rng: &mut Rng, k: usize) -> ParetoRouter {
    let budget = 10f64.powf(-4.5 + rng.f64() * 2.0);
    let mut r = ParetoRouter::new(RouterConfig::paretobandit(D, budget, rng.next_u64()));
    for i in 0..k {
        let pin = 10f64.powf(-1.5 + rng.f64() * 2.5);
        let pout = pin * (1.0 + rng.f64() * 8.0);
        r.add_model(&format!("m{i}"), pin, pout, Prior::Cold);
    }
    r
}

#[test]
fn routes_only_active_arms_and_respects_ceiling() {
    prop::for_cases(40, 11, |rng, _| {
        let k = 2 + rng.below(5);
        let mut r = random_portfolio(rng, k);
        for step in 0..300 {
            let x = ctx(rng);
            let d = r.route(&x);
            assert!(r.registry().is_active(d.arm), "routed retired arm");
            // two-layer enforcement invariant: when λ>0, the chosen arm's
            // blended price obeys the dynamic ceiling (or is the cheapest
            // fallback)
            if d.lambda > 0.0 && !d.forced {
                let e = r.registry().get(d.arm).unwrap();
                let ceiling = r.registry().max_blended() / (1.0 + d.lambda);
                let cheapest = r.registry().cheapest_active().unwrap();
                assert!(
                    e.blended_per_1k <= ceiling + 1e-12 || d.arm == cheapest,
                    "step {step}: ceiling violated"
                );
            }
            r.feedback(d.arm, &x, rng.f64(), 1e-5 + rng.f64() * 1e-3);
        }
        // dual variable stays projected to [0, λ̄]
        let lam = r.pacer().unwrap().lambda();
        assert!((0.0..=5.0).contains(&lam), "λ={lam}");
    });
}

#[test]
fn hot_swap_storm_keeps_router_consistent() {
    // add/delete models randomly while routing — slot alignment, burn-in
    // and candidate sets must stay coherent
    prop::for_cases(25, 12, |rng, _| {
        let mut r = random_portfolio(rng, 3);
        let mut live: Vec<usize> = vec![0, 1, 2];
        for _ in 0..400 {
            match rng.below(20) {
                0 => {
                    let pin = 10f64.powf(-1.5 + rng.f64() * 2.5);
                    let id = r.add_model("new", pin, pin * 3.0, Prior::Heuristic {
                        n_eff: 10.0,
                        r0: 0.5,
                    });
                    live.push(id);
                }
                1 if live.len() > 1 => {
                    let idx = rng.below(live.len());
                    let id = live.swap_remove(idx);
                    assert!(r.delete_model(id));
                    assert!(!r.delete_model(id), "double delete must fail");
                }
                _ => {}
            }
            let x = ctx(rng);
            let d = r.route(&x);
            assert!(live.contains(&d.arm), "routed dead arm {}", d.arm);
            r.feedback(d.arm, &x, rng.f64(), 1e-4);
        }
    });
}

#[test]
fn feedback_failure_injection_is_harmless() {
    // junk feedback must never corrupt state or panic: unknown arms,
    // deleted arms, extreme rewards/costs
    prop::for_cases(25, 13, |rng, _| {
        let mut r = random_portfolio(rng, 3);
        for _ in 0..200 {
            let x = ctx(rng);
            let d = r.route(&x);
            match rng.below(6) {
                0 => r.feedback(99, &x, 0.5, 1e-4),          // unknown arm
                1 => r.feedback(d.arm, &x, f64::MAX, 1e-4),  // absurd reward
                2 => r.feedback(d.arm, &x, 0.9, 0.0),        // zero cost
                3 => r.feedback(d.arm, &x, -5.0, 1e9),       // negative / huge
                _ => r.feedback(d.arm, &x, rng.f64(), 1e-4),
            }
        }
        // router still functions and λ is still projected
        let x = ctx(rng);
        let d = r.route(&x);
        assert!(r.registry().is_active(d.arm));
        let lam = r.pacer().unwrap().lambda();
        assert!((0.0..=5.0).contains(&lam) && lam.is_finite());
    });
}

#[test]
fn spend_rate_tracks_any_ceiling_in_steady_state() {
    // randomized budgets & portfolios: after convergence the realised rate
    // must not exceed ~1.2x the ceiling when the cheapest arm is affordable
    prop::for_cases(15, 14, |rng, _| {
        let k = 3;
        let mut r = random_portfolio(rng, k);
        let budget = r.pacer().unwrap().budget();
        let cheapest_rate = {
            let id = r.registry().cheapest_active().unwrap();
            r.registry().get(id).unwrap().blended_per_1k
        };
        // synthetic per-arm costs proportional to blended rates
        let costs: Vec<f64> = (0..k)
            .map(|i| r.registry().get(i).unwrap().blended_per_1k * 0.4)
            .collect();
        if costs.iter().cloned().fold(f64::MAX, f64::min) > budget {
            return; // even the cheapest arm violates the ceiling: skip
        }
        let mut spend = 0.0;
        let steps = 1500;
        for i in 0..steps {
            let x = ctx(rng);
            let d = r.route(&x);
            let c = costs[d.arm] * (0.5 + rng.f64());
            if i >= 500 {
                spend += c;
            }
            r.feedback(d.arm, &x, rng.f64() * 0.3 + 0.6, c);
        }
        let rate = spend / (steps - 500) as f64;
        assert!(
            rate <= budget * 1.25 + cheapest_rate,
            "rate {rate} vs budget {budget}"
        );
    });
}

#[test]
fn context_cache_under_duplicate_and_unknown_ids() {
    let mut cache = ContextCache::new(64);
    let mut rng = Rng::new(15);
    for i in 0..500u64 {
        cache.insert(Pending {
            request_id: i % 100, // forced duplicates
            arm: rng.below(3),
            context: vec![rng.f64(); 4],
        });
        if rng.bernoulli(0.5) {
            let _ = cache.take(rng.next_u64() % 200); // unknown ids ok
        }
        assert!(cache.len() <= 64);
    }
}

#[test]
fn deterministic_replay_per_seed() {
    // identical seeds + identical traffic => identical decisions
    let run = |seed: u64| -> Vec<usize> {
        let mut rng = Rng::new(999);
        let mut r = ParetoRouter::new(RouterConfig::paretobandit(D, 5e-4, seed));
        r.add_model("a", 0.1, 0.1, Prior::Cold);
        r.add_model("b", 0.4, 1.6, Prior::Cold);
        (0..200)
            .map(|_| {
                let x = ctx(&mut rng);
                let d = r.route(&x);
                r.feedback(d.arm, &x, rng.f64(), 1e-4);
                d.arm
            })
            .collect()
    };
    assert_eq!(run(7), run(7));
    // (different tiebreak seeds may legitimately coincide under UCB —
    // scores are deterministic and exact ties are rare after learning)
}
