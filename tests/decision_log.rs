//! Decision-log format suite: property round-trips over randomized
//! records, crc rejection of corrupted frames, clean recovery from a
//! truncated tail (mid-frame crash), segment rotation, and the shared
//! capture clock interleaving shard streams.

use std::path::PathBuf;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use paretobandit::log::{
    read_log_dir, read_segment, AdminOp, AdminRec, CaptureMeta, DecisionRec, EligibleSlot,
    FeedbackRec, LogWriter, ModelMeta, Record,
};
use paretobandit::util::prop::for_cases;
use paretobandit::util::rng::Rng;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pb_declog_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn sample_meta(shard: u32) -> CaptureMeta {
    CaptureMeta {
        shard,
        d: 6,
        seed: 42 + shard as u64,
        budget: Some(6.6e-4),
        policy: "paretobandit".into(),
        warm: false,
        models: vec![
            Some(ModelMeta {
                name: "llama-3.1-8b".into(),
                price_in: 0.10,
                price_out: 0.10,
                prior: Some((25.0, 0.7)),
            }),
            Some(ModelMeta {
                name: "gemini-2.5-pro".into(),
                price_in: 1.25,
                price_out: 10.0,
                prior: None,
            }),
        ],
    }
}

fn rand_meta(rng: &mut Rng) -> CaptureMeta {
    let n_models = rng.below(5);
    CaptureMeta {
        shard: rng.below(8) as u32,
        d: 1 + rng.below(32) as u32,
        seed: rng.next_u64(),
        budget: if rng.bernoulli(0.7) {
            Some(rng.f64() * 1e-3)
        } else {
            None
        },
        policy: format!("policy-{}", rng.below(100)),
        warm: rng.bernoulli(0.3),
        models: (0..n_models)
            .map(|i| {
                if rng.bernoulli(0.2) {
                    None
                } else {
                    Some(ModelMeta {
                        name: format!("model-{i}-\u{03bb}"),
                        price_in: rng.f64() * 5.0,
                        price_out: rng.f64() * 20.0,
                        prior: if rng.bernoulli(0.5) {
                            Some((rng.f64() * 50.0, rng.f64()))
                        } else {
                            None
                        },
                    })
                }
            })
            .collect(),
    }
}

fn rand_admin_op(rng: &mut Rng) -> AdminOp {
    match rng.below(6) {
        0 => AdminOp::AddModel {
            name: format!("m{}", rng.below(1000)),
            price_in: rng.f64() * 5.0,
            price_out: rng.f64() * 20.0,
            prior: if rng.bernoulli(0.5) {
                Some((rng.f64() * 40.0, rng.f64()))
            } else {
                None
            },
        },
        1 => AdminOp::DeleteModel {
            slot: rng.below(16) as u32,
        },
        2 => AdminOp::Reprice {
            slot: rng.below(16) as u32,
            price_in: rng.f64() * 5.0,
            price_out: rng.f64() * 20.0,
        },
        3 => AdminOp::SetBudget {
            budget: rng.f64() * 1e-2,
        },
        4 => AdminOp::Restore,
        _ => AdminOp::SyncBarrier,
    }
}

fn rand_record(rng: &mut Rng, seq: u64) -> Record {
    // a sprinkling of awkward but PartialEq-stable floats
    let odd = [0.0, -0.0, f64::INFINITY, f64::MIN_POSITIVE, 1e308];
    let f = |rng: &mut Rng| {
        if rng.bernoulli(0.1) {
            odd[rng.below(odd.len())]
        } else {
            rng.normal() * 10.0
        }
    };
    match rng.below(4) {
        0 => Record::Header(rand_meta(rng)),
        1 => Record::Decision(DecisionRec {
            seq,
            t: rng.next_u64() >> 20,
            request_id: rng.next_u64() >> 10,
            lambda: f(rng),
            arm: rng.below(16) as u32,
            forced: rng.bernoulli(0.2),
            n_eligible: rng.below(16) as u32,
            x: (0..rng.below(12)).map(|_| f(rng)).collect(),
            eligible: (0..rng.below(6))
                .map(|i| EligibleSlot {
                    slot: i as u32,
                    blended: f(rng),
                    c_tilde: f(rng),
                })
                .collect(),
        }),
        2 => Record::Feedback(FeedbackRec {
            seq,
            request_id: rng.next_u64() >> 10,
            arm: rng.below(16) as u32,
            reward: f(rng),
            cost: f(rng),
            queued: rng.bernoulli(0.5),
        }),
        _ => Record::Admin(AdminRec {
            seq,
            op: rand_admin_op(rng),
        }),
    }
}

#[test]
fn property_randomized_records_roundtrip_exactly() {
    for_cases(300, 0xD06, |rng, case| {
        let rec = rand_record(rng, case as u64);
        let mut buf = Vec::new();
        rec.encode(&mut buf);
        let back = Record::decode(&buf).unwrap_or_else(|e| panic!("decode {rec:?}: {e}"));
        assert_eq!(back, rec, "roundtrip drift");
        // truncating any prefix of the payload must be rejected, never
        // misdecoded (full-consumption rule)
        if buf.len() > 1 {
            let cut = 1 + rng.below(buf.len() - 1);
            assert!(
                Record::decode(&buf[..cut]).is_err(),
                "decode accepted a {cut}-byte prefix of a {}-byte payload",
                buf.len()
            );
        }
    });
}

/// Append a deterministic little traffic mix; returns the records the
/// reader should hand back, in order.
fn append_mix(w: &mut LogWriter, n: usize) -> Vec<Record> {
    let mut expect = Vec::new();
    for i in 0..n {
        let x = [0.25 * i as f64, -1.0, 1.0];
        let eligible = [0usize, 1usize];
        let blended = [1e-4, 5.6e-3];
        let c_tilde = [0.2, 0.9];
        let seq = w
            .append_decision(
                i as u64,
                1000 + i as u64,
                0.125 * i as f64,
                (i % 2) as u32,
                false,
                2,
                &x,
                &eligible,
                &blended,
                &c_tilde,
            )
            .expect("append_decision");
        expect.push(Record::Decision(DecisionRec {
            seq,
            t: i as u64,
            request_id: 1000 + i as u64,
            lambda: 0.125 * i as f64,
            arm: (i % 2) as u32,
            forced: false,
            n_eligible: 2,
            x: x.to_vec(),
            eligible: eligible
                .iter()
                .map(|&s| EligibleSlot {
                    slot: s as u32,
                    blended: blended[s],
                    c_tilde: c_tilde[s],
                })
                .collect(),
        }));
        let seq = w
            .append_feedback(1000 + i as u64, (i % 2) as u32, 0.75, 2.9e-5, true)
            .expect("append_feedback");
        expect.push(Record::Feedback(FeedbackRec {
            seq,
            request_id: 1000 + i as u64,
            arm: (i % 2) as u32,
            reward: 0.75,
            cost: 2.9e-5,
            queued: true,
        }));
        if i % 5 == 4 {
            let op = AdminOp::SyncBarrier;
            let seq = w.append_admin(&op).expect("append_admin");
            expect.push(Record::Admin(AdminRec { seq, op }));
        }
    }
    expect
}

#[test]
fn writer_reader_roundtrip_with_contiguous_seqs() {
    let dir = temp_dir("roundtrip");
    let mut w = LogWriter::create(&dir, sample_meta(0), u64::MAX).unwrap();
    let expect = append_mix(&mut w, 10);
    drop(w); // Drop flushes

    let log = read_log_dir(&dir).unwrap();
    assert!(!log.damaged());
    assert_eq!(log.shards.len(), 1);
    let stream = log.shards.get(&0).unwrap();
    assert_eq!(stream.meta, sample_meta(0));
    assert_eq!(stream.records, expect);
    // the private clock hands out 0..n contiguously
    let seqs: Vec<u64> = stream.records.iter().map(Record::seq).collect();
    assert_eq!(seqs, (0..expect.len() as u64).collect::<Vec<_>>());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Parse `[len][crc][payload]` frame spans: (start offset, total bytes).
fn frame_spans(bytes: &[u8]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut pos = 0usize;
    while pos + 8 <= bytes.len() {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        if pos + 8 + len > bytes.len() {
            break;
        }
        spans.push((pos, 8 + len));
        pos += 8 + len;
    }
    spans
}

fn single_segment_path(dir: &std::path::Path) -> PathBuf {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    assert_eq!(paths.len(), 1, "expected one segment: {paths:?}");
    paths.pop().unwrap()
}

#[test]
fn crc_mismatch_rejects_the_frame_and_keeps_the_prefix() {
    let dir = temp_dir("crc");
    let mut w = LogWriter::create(&dir, sample_meta(0), u64::MAX).unwrap();
    let expect = append_mix(&mut w, 6);
    drop(w);

    let path = single_segment_path(&dir);
    let clean = std::fs::read(&path).unwrap();
    let spans = frame_spans(&clean);
    assert_eq!(spans.len(), expect.len() + 1, "header + records");

    // flip one payload byte in each record frame in turn (frame 0 is the
    // header): everything before the damage survives, nothing after
    for k in 1..spans.len() {
        let mut bytes = clean.clone();
        let (start, _) = spans[k];
        bytes[start + 8] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let seg = read_segment(&path).unwrap();
        assert!(seg.corrupt, "frame {k}: damage must be flagged");
        assert!(!seg.truncated);
        assert_eq!(seg.records, expect[..k - 1], "frame {k}: intact prefix");
        // the dir-level reader agrees and surfaces the damage
        let log = read_log_dir(&dir).unwrap();
        assert!(log.damaged());
        assert_eq!(log.n_records(), k - 1);
    }

    // a corrupted header orphans the whole segment
    let mut bytes = clean.clone();
    bytes[spans[0].0 + 8] ^= 0xff;
    std::fs::write(&path, &bytes).unwrap();
    let seg = read_segment(&path).unwrap();
    assert!(seg.corrupt && seg.meta.is_none() && seg.records.is_empty());
    assert!(read_log_dir(&dir).is_err(), "no readable header left");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_tail_recovers_the_intact_prefix() {
    let dir = temp_dir("trunc");
    let mut w = LogWriter::create(&dir, sample_meta(0), u64::MAX).unwrap();
    let expect = append_mix(&mut w, 6);
    drop(w);

    let path = single_segment_path(&dir);
    let clean = std::fs::read(&path).unwrap();
    let spans = frame_spans(&clean);

    // cut at every byte inside the last record frame (mid-frame crash):
    // the prefix reads back clean, the tail is flagged, never misread
    let (last_start, last_len) = *spans.last().unwrap();
    for cut in last_start..last_start + last_len - 1 {
        std::fs::write(&path, &clean[..cut + 1]).unwrap();
        let seg = read_segment(&path).unwrap();
        assert!(seg.truncated, "cut at {cut}: must flag truncation");
        assert!(!seg.corrupt);
        assert_eq!(seg.records, expect[..expect.len() - 1]);
    }

    // a cut exactly on a frame boundary is a clean file
    std::fs::write(&path, &clean[..last_start]).unwrap();
    let seg = read_segment(&path).unwrap();
    assert!(!seg.truncated && !seg.corrupt);
    assert_eq!(seg.records, expect[..expect.len() - 1]);

    // dir-level: the truncated flag propagates
    std::fs::write(&path, &clean[..clean.len() - 3]).unwrap();
    let log = read_log_dir(&dir).unwrap();
    assert!(log.damaged());
    assert_eq!(log.n_records(), expect.len() - 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn property_random_cuts_never_misread() {
    // one clean capture, arbitrary crash points: the reader must always
    // return a strict prefix of the written records
    let dir = temp_dir("propcut");
    let mut w = LogWriter::create(&dir, sample_meta(0), u64::MAX).unwrap();
    let expect = append_mix(&mut w, 12);
    drop(w);
    let path = single_segment_path(&dir);
    let clean = std::fs::read(&path).unwrap();
    let mut boundaries = vec![0usize];
    for (start, len) in frame_spans(&clean) {
        boundaries.push(start + len);
    }
    for_cases(60, 0xC07, |rng, _| {
        let cut = rng.below(clean.len());
        std::fs::write(&path, &clean[..cut]).unwrap();
        let seg = read_segment(&path).unwrap();
        let n = seg.records.len();
        assert!(n <= expect.len());
        assert_eq!(seg.records, expect[..n], "cut at {cut}: not a prefix");
        assert!(!seg.corrupt, "cut at {cut}: truncation misread as damage");
        // a cut on a frame boundary is a clean (shorter) file; anywhere
        // else must be flagged, never silently swallowed
        assert_eq!(
            seg.truncated,
            !boundaries.contains(&cut),
            "cut at {cut}: wrong truncation flag"
        );
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rotation_splits_segments_and_the_reader_merges_them() {
    let dir = temp_dir("rotate");
    // 4096 is the clamp floor; a record frame is ~100 bytes, so 40
    // records split across several segments
    let mut w = LogWriter::create(&dir, sample_meta(0), 1).unwrap();
    let expect = append_mix(&mut w, 40);
    drop(w);

    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    paths.sort();
    assert!(paths.len() >= 2, "rotation never fired: {paths:?}");
    for p in &paths {
        let seg = read_segment(p).unwrap();
        assert!(!seg.truncated && !seg.corrupt);
        // every segment is self-describing
        assert_eq!(seg.meta, Some(sample_meta(0)), "{}", p.display());
    }
    let log = read_log_dir(&dir).unwrap();
    assert!(!log.damaged());
    let stream = log.shards.get(&0).unwrap();
    assert_eq!(stream.records, expect, "merge must restore append order");
    // losing the tail segment only loses the tail records
    let last = paths.pop().unwrap();
    let kept_before = read_segment(&last).unwrap().records.len();
    std::fs::remove_file(&last).unwrap();
    let log = read_log_dir(&dir).unwrap();
    assert_eq!(log.n_records(), expect.len() - kept_before);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shared_clock_orders_records_across_shard_writers() {
    let dir = temp_dir("shared");
    let clock = Arc::new(AtomicU64::new(0));
    let mut w0 = LogWriter::with_clock(&dir, sample_meta(0), u64::MAX, clock.clone()).unwrap();
    let mut w1 = LogWriter::with_clock(&dir, sample_meta(1), u64::MAX, clock.clone()).unwrap();
    // interleave appends: the ticket order is the append order
    let mut order = Vec::new();
    for i in 0..20u64 {
        let (w, shard) = if i % 3 == 0 { (&mut w1, 1u32) } else { (&mut w0, 0u32) };
        let seq = w.append_feedback(i, 0, 0.5, 1e-4, false).unwrap();
        order.push((seq, shard, i));
    }
    drop(w0);
    drop(w1);

    let log = read_log_dir(&dir).unwrap();
    assert_eq!(log.shards.len(), 2);
    assert_eq!(log.n_records(), 20);
    // global_order() must reproduce the append interleaving exactly
    let merged = log.global_order();
    assert_eq!(merged.len(), 20);
    for (k, (shard, rec)) in merged.iter().enumerate() {
        let (seq, want_shard, want_id) = order[k];
        assert_eq!(rec.seq(), seq, "position {k}");
        assert_eq!(*shard, want_shard, "position {k}");
        match rec {
            Record::Feedback(f) => assert_eq!(f.request_id, want_id, "position {k}"),
            other => panic!("position {k}: unexpected {other:?}"),
        }
    }
    // seqs are one strictly increasing sequence across both writers
    assert!(merged.windows(2).all(|w| w[0].1.seq() < w[1].1.seq()));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn writer_refuses_to_clobber_and_empty_dirs_error() {
    let dir = temp_dir("clobber");
    let w = LogWriter::create(&dir, sample_meta(0), u64::MAX).unwrap();
    // same shard, same dir: segment 0 already exists
    assert!(LogWriter::create(&dir, sample_meta(0), u64::MAX).is_err());
    drop(w);
    let empty = temp_dir("empty");
    std::fs::create_dir_all(&empty).unwrap();
    assert!(read_log_dir(&empty).is_err());
    assert!(read_log_dir(&temp_dir("missing")).is_err());
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&empty);
}
