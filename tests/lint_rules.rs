//! Per-rule fixtures for `pallas-lint` plus the repo self-check.
//!
//! Each rule is exercised three ways where it makes sense: a positive hit
//! on a minimal fixture, the same fixture silenced by a well-formed
//! `lint: allow(...)` suppression, and (for atomics) the annotated form
//! that passes outright.  The final test runs the real linter over this
//! checkout and asserts it is clean against the committed
//! `LINT_baseline.json` — the same gate CI applies with `lint --deny`.

use paretobandit::analysis::rules::{check_file, check_protocol};
use paretobandit::analysis::scan::scan_source;
use paretobandit::analysis::{load_baseline, run_lint, Finding, BASELINE_FILE};

/// A path inside the serving scope (panic + index rules apply).
const SERVING: &str = "rust/src/server/fixture.rs";
/// A path outside the serving scope and the designated atomic files.
const UTIL: &str = "rust/src/util/fixture.rs";

fn findings(path: &str, src: &str) -> Vec<Finding> {
    check_file(&scan_source(path, src))
}

// ----------------------------------------------------------------------
// panic-freedom

#[test]
fn panic_rule_fires_in_serving_scope_only() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let f = findings(SERVING, src);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "panic");
    assert_eq!(f[0].line, 2);
    assert!(findings(UTIL, src).is_empty(), "panic rule leaked out of scope");
}

#[test]
fn panic_rule_suppressed_by_allow_with_reason() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic) reason=\"fixture\"\n    x.unwrap()\n}\n";
    assert!(findings(SERVING, src).is_empty());
}

#[test]
fn unwrap_or_else_does_not_match_the_unwrap_token() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap_or_else(|| 0)\n}\n";
    assert!(findings(SERVING, src).is_empty());
}

#[test]
fn reasonless_allow_is_flagged_and_suppresses_nothing() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic)\n    x.unwrap()\n}\n";
    let f = findings(SERVING, src);
    assert!(f.iter().any(|x| x.rule == "suppression"), "{f:?}");
    assert!(f.iter().any(|x| x.rule == "panic"), "{f:?}");
}

#[test]
fn cfg_test_regions_are_exempt() {
    let src = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u32>) -> u32 {\n        x.unwrap()\n    }\n}\n";
    assert!(findings(SERVING, src).is_empty());
}

// ----------------------------------------------------------------------
// indexing

#[test]
fn index_rule_fires_and_get_is_clean() {
    let f = findings(SERVING, "fn f(xs: &[u32]) -> u32 {\n    xs[0]\n}\n");
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "index");
    let ok = "fn f(xs: &[u32]) -> u32 {\n    xs.get(0).copied().unwrap_or(0)\n}\n";
    assert!(findings(SERVING, ok).is_empty());
}

#[test]
fn index_rule_suppressed_by_fn_level_allow() {
    let src = "// lint: allow(index) reason=\"fixture: i is always in bounds\"\nfn f(xs: &[u32], i: usize) -> u32 {\n    xs[i]\n}\n";
    assert!(findings(SERVING, src).is_empty());
}

// ----------------------------------------------------------------------
// atomic-ordering discipline

#[test]
fn atomic_sites_in_designated_files_need_invariant_comments() {
    let bare = "fn f(n: &std::sync::atomic::AtomicU64) -> u64 {\n    n.load(std::sync::atomic::Ordering::Acquire)\n}\n";
    let f = findings("rust/src/pacer/shared.rs", bare);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "atomics");
    let annotated = "fn f(n: &std::sync::atomic::AtomicU64) -> u64 {\n    // invariant: fixture pairing note\n    n.load(std::sync::atomic::Ordering::Acquire)\n}\n";
    assert!(findings("rust/src/pacer/shared.rs", annotated).is_empty());
}

#[test]
fn relaxed_and_seqcst_flagged_outside_designated_files() {
    let relaxed = "fn f(n: &std::sync::atomic::AtomicU64) -> u64 {\n    n.load(std::sync::atomic::Ordering::Relaxed)\n}\n";
    let f = findings(UTIL, relaxed);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "atomics");
    // acquire/release orderings are fine anywhere
    let acquire = relaxed.replace("Relaxed", "Acquire");
    assert!(findings(UTIL, &acquire).is_empty());
    // and an allow with a reason silences a deliberate Relaxed
    let allowed = "fn f(n: &std::sync::atomic::AtomicU64) -> u64 {\n    // lint: allow(atomics) reason=\"fixture: monotone counter\"\n    n.load(std::sync::atomic::Ordering::Relaxed)\n}\n";
    assert!(findings(UTIL, allowed).is_empty());
}

// ----------------------------------------------------------------------
// hot-path allocation ban

#[test]
fn no_alloc_marker_bans_allocation_inside_the_fn() {
    let marked = "// lint: no_alloc\nfn hot(xs: &[f64]) -> Vec<f64> {\n    xs.to_vec()\n}\n";
    let f = findings("rust/src/linalg/fixture.rs", marked);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "no_alloc");
    // the very same body without the marker is none of the linter's business
    let unmarked = "fn cold(xs: &[f64]) -> Vec<f64> {\n    xs.to_vec()\n}\n";
    assert!(findings("rust/src/linalg/fixture.rs", unmarked).is_empty());
}

#[test]
fn no_alloc_span_ends_with_the_fn() {
    let src = "// lint: no_alloc\nfn hot(xs: &mut [f64]) {\n    xs.sort_unstable_by(f64::total_cmp);\n}\n\nfn after(xs: &[f64]) -> Vec<f64> {\n    xs.to_vec()\n}\n";
    assert!(findings("rust/src/linalg/fixture.rs", src).is_empty());
}

// ----------------------------------------------------------------------
// wire-protocol exhaustiveness

const PROTO_SRC: &str =
    "fn parse(op: &str) -> u32 {\n    match op {\n        \"route\" => 1,\n        _ => 0,\n    }\n}\n";

fn proto_findings(client_src: &str, readme: &str) -> Vec<Finding> {
    let scans = vec![
        scan_source("rust/src/server/proto.rs", PROTO_SRC),
        scan_source(
            "rust/src/server/api.rs",
            "fn d(r: Request) {\n    let _ = matches!(r, Request::Route);\n}\n",
        ),
        scan_source("rust/src/client.rs", client_src),
    ];
    check_protocol(&scans, readme)
}

#[test]
fn proto_rule_checks_client_methods_and_readme_rows() {
    let client = "pub fn route(x: u32) -> u32 {\n    x\n}\n";
    let row = "| `route` | one routing decision |";
    assert!(proto_findings(client, row).is_empty());

    let f = proto_findings("fn unrelated() {}\n", row);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "proto");
    assert!(f[0].msg.contains("ParetoClient"), "{}", f[0].msg);

    let f = proto_findings(client, "no protocol table here");
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].msg.contains("README"), "{}", f[0].msg);
}

#[test]
fn proto_rule_accepts_generic_client_methods() {
    let client = "pub fn route<S: AsRef<str>>(x: S) -> u32 {\n    1\n}\n";
    assert!(proto_findings(client, "| `route` | one routing decision |").is_empty());
}

// ----------------------------------------------------------------------
// repo self-check: the gate CI applies

#[test]
fn repository_is_clean_against_the_committed_baseline() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = run_lint(root).expect("lint run over the checkout");
    let baseline_path = root.join(BASELINE_FILE);
    let baseline = load_baseline(baseline_path.to_str().expect("utf-8 path"))
        .expect("parse committed baseline");
    let viols: Vec<String> = report
        .violations(&baseline)
        .iter()
        .map(|v| format!("{}: {} > allowance {}", v.key, v.current, v.baseline))
        .collect();
    assert!(viols.is_empty(), "baseline exceeded:\n{}", viols.join("\n"));

    // acceptance areas hold a hard zero, not a baselined allowance
    for f in &report.findings {
        assert!(
            !f.file.ends_with("server/api.rs")
                && !f.file.ends_with("server/serve.rs")
                && !f.file.ends_with("pacer/shared.rs"),
            "acceptance-critical file regressed: {}:{} [{}] {}",
            f.file,
            f.line,
            f.rule,
            f.msg
        );
        assert_ne!(
            f.rule, "no_alloc",
            "hot-path fn allocates: {}:{} {}",
            f.file, f.line, f.msg
        );
    }
}
